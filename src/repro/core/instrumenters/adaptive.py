"""Adaptive sampling instrumenter — PEP 669 epochs with per-code backoff.

The governor ladder rung between ``sampling`` and ``none``: where the
counting sampler still pays a per-call countdown on *every* call, this
instrumenter pays nothing at all for unsampled calls.  Each callback records
one sample and returns ``sys.monitoring.DISABLE``, retiring its (code,
location) until a controller thread calls ``restart_events()`` — so between
epochs the interpreter runs at native speed, and the steady-state cost is
bounded by the sample rate, not the call rate.

Two feedback loops shape the sample stream (cf. scalene's adaptive sampling:
grow the effective period for signals that keep firing, decay so nothing is
starved forever):

* **Global epoch interval** — the controller compares the observed sample
  rate against ``target_rate`` (sampled call pairs per second) and
  doubles/halves the epoch interval within [``min_interval``,
  ``max_interval``].  Many live code objects -> longer epochs; sparse
  signal -> shorter epochs.
* **Per-code period** — a code object sampled in ``grow_streak`` consecutive
  epochs doubles its personal epoch period (up to ``max_code_period``):
  persistently hot functions skip whole epochs while rare ones stay at
  period 1.  Every ``decay_epochs`` epochs the per-code state is cleared so
  cooled-down regions are re-observed from scratch.

Sampled enters are balanced by a per-code pending count: the matching
PY_RETURN/PY_YIELD records the exit (a return with nothing pending is
DISABLEd away).  An exit may land in a later epoch than its enter — the
recorded span then brackets the true one, which downstream replay already
tolerates (same approximation class as the counting sampler's shadow stack).
Filtered verdicts behave exactly like the monitoring instrumenter: DISABLE
on first hit, zero cost afterwards, re-armed by the refilter hook.
"""

from __future__ import annotations

import sys
import threading
import time

from ..buffer import EV_ENTER, EV_EXIT
from .base import Instrumenter
from .monitoring import _TOOL_NAME, acquire_tool_id

DEFAULT_TARGET_RATE = 4000.0  # sampled call pairs per second
MIN_INTERVAL = 0.002
MAX_INTERVAL = 0.5
MAX_CODE_PERIOD = 64  # epochs skipped by the hottest code objects
GROW_STREAK = 4  # consecutive sampled epochs before the period doubles
DECAY_EPOCHS = 64  # epochs between per-code state resets


class AdaptiveInstrumenter(Instrumenter):
    name = "adaptive"
    events_supported = ("call", "return")
    downgrade_to = "none"
    zero_cost_filtered = True

    def __init__(self, target_rate: float = DEFAULT_TARGET_RATE, interval: float = 0.01) -> None:
        if target_rate <= 0:
            raise ValueError("adaptive target_rate must be > 0 (samples/s)")
        if not MIN_INTERVAL <= interval <= MAX_INTERVAL:
            raise ValueError(
                f"adaptive interval must be in [{MIN_INTERVAL}, {MAX_INTERVAL}]"
            )
        self.target_rate = float(target_rate)
        # Shared cell: the controller adapts it live; exposed for tests.
        self._interval_cell = [float(interval)]
        self._measurement = None
        self._installed = False
        self._tool_id = None
        self._regions = None
        self._nfiltered: list = [0]
        self._nsampled: list = [0]
        self._epoch = 0
        # code object -> [epochs_to_skip, period, streak]
        self._code_state: dict = {}
        # code object -> count of sampled enters awaiting their exit
        self._pending: dict = {}
        self._stop = threading.Event()
        self._controller = None

    def filtered_calls(self) -> int:
        return self._nfiltered[0]

    def sampled_calls(self) -> int:
        return self._nsampled[0]

    @property
    def interval(self) -> float:
        return self._interval_cell[0]

    def _make_callbacks(self, measurement):
        mon = sys.monitoring
        DISABLE = mon.DISABLE
        regions = measurement.regions
        by_code = regions.by_code
        register_code = regions.register_code
        clock = time.perf_counter_ns
        get_ident = threading.get_ident
        appends = {}
        buffers = {}

        def _bind(ident):
            buf = measurement.thread_buffer()
            buffers[ident] = buf
            appends[ident] = buf.events.append
            return appends[ident]

        def _maybe_flush(ident):
            buf = buffers[ident]
            if len(buf.events) >= buf.flush_threshold:
                buf.flush()
                appends[ident] = buf.events.append

        nfiltered = self._nfiltered
        nsampled = self._nsampled
        code_state = self._code_state
        pending = self._pending

        def on_start(code, instruction_offset):
            t = clock()
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, None)
            if rid < 0:
                nfiltered[0] += 1
                return DISABLE
            st = code_state.get(code)
            if st is None:
                st = code_state[code] = [0, 1, 0]
            elif st[0] > 0:
                # Backed-off code object: sit this epoch out entirely.
                st[0] -= 1
                return DISABLE
            ident = get_ident()
            append = appends.get(ident)
            if append is None:
                append = _bind(ident)
            append((EV_ENTER, rid, t, 0))
            _maybe_flush(ident)
            nsampled[0] += 1
            pending[code] = pending.get(code, 0) + 1
            st[2] += 1
            if st[2] >= GROW_STREAK:
                st[1] = min(st[1] * 2, MAX_CODE_PERIOD)
                st[2] = 0
            st[0] = st[1] - 1
            return DISABLE

        def on_return(code, instruction_offset, retval):
            t = clock()
            n = pending.get(code)
            if not n:
                # No sampled enter waiting for this code: go dark until the
                # next epoch re-arms returns alongside starts.
                return DISABLE
            rid = by_code.get(code)
            if rid is None or rid < 0:
                # Verdict flipped (refilter) between enter and exit: drop
                # the orphaned enters rather than record a filtered region.
                pending.pop(code, None)
                return DISABLE
            ident = get_ident()
            append = appends.get(ident)
            if append is None:
                append = _bind(ident)
            append((EV_EXIT, rid, t, 0))
            _maybe_flush(ident)
            if n == 1:
                del pending[code]
                return DISABLE
            pending[code] = n - 1
            # More enters pending (recursion): keep the return armed.
            return None

        def on_unwind(code, instruction_offset, exception):
            # Not locally disableable; balance like a return, return None.
            on_return(code, instruction_offset, None)

        return on_start, on_return, on_unwind

    # -- controller ---------------------------------------------------------

    def _controller_loop(self) -> None:
        mon = sys.monitoring
        last = 0
        while not self._stop.wait(self._interval_cell[0]):
            if not self._installed:
                return
            n = self._nsampled[0]
            delta = n - last
            last = n
            interval = self._interval_cell[0]
            rate = delta / interval
            if rate > 2.0 * self.target_rate:
                self._interval_cell[0] = min(interval * 2.0, MAX_INTERVAL)
            elif delta and rate < 0.5 * self.target_rate:
                self._interval_cell[0] = max(interval / 2.0, MIN_INTERVAL)
            self._epoch += 1
            if self._epoch % DECAY_EPOCHS == 0:
                self._code_state.clear()
            try:
                mon.restart_events()
            except Exception:  # pragma: no cover - interpreter shutdown
                return

    def _rearm(self) -> None:
        if self._installed:
            sys.monitoring.restart_events()

    # -- lifecycle ----------------------------------------------------------

    def install(self, measurement) -> None:
        mon = sys.monitoring
        tool_id = acquire_tool_id(mon, _TOOL_NAME)
        self._tool_id = tool_id
        self._measurement = measurement
        self._regions = measurement.regions
        self._code_state = {}
        self._pending = {}
        on_start, on_return, on_unwind = self._make_callbacks(measurement)
        ev = mon.events
        mon.register_callback(tool_id, ev.PY_START, on_start)
        mon.register_callback(tool_id, ev.PY_RESUME, on_start)
        mon.register_callback(tool_id, ev.PY_RETURN, on_return)
        mon.register_callback(tool_id, ev.PY_YIELD, on_return)
        mon.register_callback(tool_id, ev.PY_UNWIND, on_unwind)
        mon.set_events(
            tool_id, ev.PY_START | ev.PY_RESUME | ev.PY_RETURN | ev.PY_YIELD | ev.PY_UNWIND
        )
        # Clear DISABLE state left by prior measurements/probes (it lives on
        # code objects, not the tool id).
        mon.restart_events()
        self._regions.add_refilter_hook(self._rearm)
        self._installed = True
        self._stop = threading.Event()
        self._controller = threading.Thread(
            target=self._controller_loop, name="repro-adaptive", daemon=True
        )
        self._controller.start()

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._installed = False
        self._stop.set()
        if self._controller is not None:
            self._controller.join(timeout=1.0)
            self._controller = None
        if self._regions is not None:
            self._regions.remove_refilter_hook(self._rearm)
            self._regions = None
        mon = sys.monitoring
        ev = mon.events
        mon.set_events(self._tool_id, 0)
        for kind in (ev.PY_START, ev.PY_RESUME, ev.PY_RETURN, ev.PY_YIELD, ev.PY_UNWIND):
            mon.register_callback(self._tool_id, kind, None)
        mon.free_tool_id(self._tool_id)
        self._tool_id = None
