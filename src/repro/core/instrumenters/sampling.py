"""Counting-sampler instrumenter — the paper's future-work item, implemented.

"Further work might include ways to control the runtime overhead […] One
approach could be to sample Python applications." (paper §5)

Design: a call-count sampler on top of ``sys.setprofile``.  Every ``period``-th
*call* event is sampled; a per-thread shadow stack of booleans tracks which
active frames were sampled so their matching *return* is recorded too (a
sampled enter without its exit would corrupt profiles).  Unsampled calls pay
only a closure-local countdown decrement + a list push — no dict lookup, no
modulo, no clock read, no region lookup, no buffer append — so β drops
roughly by the sampling ratio for call-dominated workloads (measured in
EXPERIMENTS.md §Perf).  ``c_call``-family events carry no frame identity to
balance against and are dispatched out after the two event-name compares —
they never touch the counter or the stack.

The period lives in a shared mutable cell read at every countdown *reset*
(not per event), so the overhead governor can raise it on a live measurement
(``set_period``) and every thread's callback converges within one period.
"""
# repro-lint: allow-file=SP201 — this module IS an instrumenter; installing
# the interpreter hook is its job, not a collision with itself.

from __future__ import annotations

import sys
import threading
import time

from ..buffer import EV_ENTER, EV_EXIT
from .base import Instrumenter


class SamplingInstrumenter(Instrumenter):
    name = "sampling"
    events_supported = ("call", "return")
    # On 3.12+ the next rung down is the PEP 669 adaptive sampler (zero cost
    # for unsampled calls); older interpreters fall straight through to none.
    downgrade_to = "adaptive" if hasattr(sys, "monitoring") else "none"

    def __init__(self, period: int = 97) -> None:
        if period < 1:
            raise ValueError("sampling period must be >= 1")
        self.period = period
        # Shared cell: per-thread callbacks read it on countdown reset, so a
        # live set_period() propagates without rebuilding closures.
        self._period_cell = [period]
        self._measurement = None
        self._installed = False
        # Liveness cell checked by every per-thread closure (see
        # ProfileInstrumenter): uninstall only clears the hook on the calling
        # thread, so stale worker-thread callbacks must self-remove.
        self._active: list = [False]
        self._nfiltered: list = [0]

    def filtered_calls(self) -> int:
        # In sampled calls; ``cost_multiplier`` scales it to hook events.
        return self._nfiltered[0]

    def set_period(self, period: int) -> bool:
        if period < 1:
            raise ValueError("sampling period must be >= 1")
        self.period = period
        self._period_cell[0] = period
        return True

    def cost_multiplier(self) -> float:
        return float(self.period)

    def _make_callback(self, measurement):
        active = self._active
        buf = measurement.thread_buffer()
        append = buf.events.append
        flush = buf.flush
        threshold = buf.flush_threshold
        events = buf.events
        regions = measurement.regions
        by_code = regions.by_code
        register_code = regions.register_code
        clock = time.perf_counter_ns
        period_cell = self._period_cell
        nfiltered = self._nfiltered

        # Per-thread state lives in the closure: a countdown to the next
        # sample (nonlocal int — cheaper than a dict slot + modulo) and the
        # sampled-frame boolean stack.
        remaining = period_cell[0]
        stack = []
        push = stack.append
        pop = stack.pop

        def callback(frame, event, arg):
            nonlocal remaining
            if not active[0]:
                sys.setprofile(None)  # stale generation: self-remove
                return
            if event == "call":
                remaining -= 1
                if remaining:
                    push(False)
                    return
                remaining = period_cell[0]
                code = frame.f_code
                rid = by_code.get(code)
                if rid is None:
                    rid = register_code(code, frame)
                if rid >= 0:
                    append((EV_ENTER, rid, clock(), 0))
                    if len(events) >= threshold:
                        flush()
                    push(True)
                else:
                    # Verdict-miss count (sampled calls only) so the
                    # governor can observe residual hook cost.
                    nfiltered[0] += 1
                    push(False)
            elif event == "return":
                if stack and pop():
                    code = frame.f_code
                    rid = by_code.get(code)
                    if rid is None:
                        rid = register_code(code, frame)
                    if rid >= 0:
                        append((EV_EXIT, rid, clock(), 0))
                        if len(events) >= threshold:
                            flush()
            # c_call / c_return / c_exception: dispatched out above — no
            # counter, no stack, no per-event cost beyond the two compares.

        return callback

    def _thread_entry(self, frame, event, arg):
        if not self._active[0]:
            sys.setprofile(None)
            return None
        callback = self._make_callback(self._measurement)
        sys.setprofile(callback)
        return callback(frame, event, arg)

    def install(self, measurement) -> None:
        self._measurement = measurement
        self._active = [True]
        threading.setprofile(self._thread_entry)
        sys.setprofile(self._make_callback(measurement))
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._active[0] = False
        sys.setprofile(None)
        threading.setprofile(None)
        self._installed = False
