"""``sys.settrace`` instrumenter — per-line measurement.

Observes call / return / line / exception (paper Table 1; no c_* events).
Line events carry the line number in the event's ``aux`` field.  The paper
measures this instrumenter to be strictly more expensive than
``sys.setprofile`` (+0.8 µs per executed line in their setup) and therefore
not the default; we reproduce that comparison in
``benchmarks/overhead_case1.py`` / ``overhead_case2.py``.
"""
# repro-lint: allow-file=SP201 — this module IS an instrumenter; installing
# the interpreter hook is its job, not a collision with itself.

from __future__ import annotations

import sys
import threading
import time

from ..buffer import EV_ENTER, EV_EXCEPTION, EV_EXIT, EV_LINE
from .base import Instrumenter


class TraceInstrumenter(Instrumenter):
    name = "trace"
    events_supported = ("call", "return", "line", "exception")
    # Governor downgrade rung: per-line settrace -> per-call setprofile.
    downgrade_to = "profile"

    def __init__(self) -> None:
        self._measurement = None
        self._installed = False
        # Liveness cell checked by every per-thread closure (see
        # ProfileInstrumenter): ``sys.settrace(None)`` in uninstall only
        # clears the hook on the calling thread.
        self._active: list = [False]
        self._nfiltered: list = [0]

    def filtered_calls(self) -> int:
        return self._nfiltered[0]

    def _make_callback(self, measurement):
        active = self._active
        buf = measurement.thread_buffer()
        append = buf.events.append
        flush = buf.flush
        threshold = buf.flush_threshold
        events = buf.events
        regions = measurement.regions
        by_code = regions.by_code
        register_code = regions.register_code
        clock = time.perf_counter_ns
        nfiltered = self._nfiltered

        def callback(frame, event, arg):
            if not active[0]:
                sys.settrace(None)  # stale generation: self-remove
                frame.f_trace = None
                return None
            t = clock()
            code = frame.f_code
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, frame)
            if rid < 0:
                if event == "call":
                    # Verdict-miss count for the governor's residual-cost
                    # observation (returning None still suppresses the
                    # frame's line events, so one count per call suffices).
                    nfiltered[0] += 1
                return None
            if event == "line":
                append((EV_LINE, rid, t, frame.f_lineno))
            elif event == "call":
                append((EV_ENTER, rid, t, 0))
            elif event == "return":
                append((EV_EXIT, rid, t, 0))
            elif event == "exception":
                append((EV_EXCEPTION, rid, t, frame.f_lineno))
            if len(events) >= threshold:
                flush()
            # Returning the callback enables local (line) tracing for the
            # frame — required by the sys.settrace contract.
            return callback

        return callback

    def _thread_entry(self, frame, event, arg):
        if not self._active[0]:
            sys.settrace(None)
            return None
        callback = self._make_callback(self._measurement)
        sys.settrace(callback)
        return callback(frame, event, arg)

    def install(self, measurement) -> None:
        self._measurement = measurement
        self._active = [True]
        threading.settrace(self._thread_entry)
        sys.settrace(self._make_callback(measurement))
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._active[0] = False
        sys.settrace(None)
        threading.settrace(None)
        self._installed = False
