"""``sys.setprofile`` instrumenter — the paper's default.

Observes call / return / c_call / c_return / c_exception (paper Table 1).
The callback is generated per thread with every hot-path name bound as a
closure local (buffer append, region dicts, clock), which is the CPython
equivalent of Score-P's per-location fast path.  CPython guarantees the
profile hook is not re-entered while the callback runs, so buffer flushes
(which execute numpy/substrate code) are safe inside the callback.
"""
# repro-lint: allow-file=SP201 — this module IS an instrumenter; installing
# the interpreter hook is its job, not a collision with itself.

from __future__ import annotations

import sys
import threading
import time

from ..buffer import EV_C_ENTER, EV_C_EXIT, EV_ENTER, EV_EXIT
from .base import Instrumenter


class ProfileInstrumenter(Instrumenter):
    name = "profile"
    events_supported = ("call", "return", "c_call", "c_return", "c_exception")
    # Governor downgrade rung: exhaustive setprofile -> counting sampler.
    downgrade_to = "sampling"

    def __init__(self) -> None:
        self._measurement = None
        self._installed = False
        # Shared liveness cell, rebound per install (a generation marker):
        # ``sys.setprofile(None)`` in uninstall only clears the hook on the
        # *calling* thread, so live worker threads keep their closure.  Each
        # callback checks this cell and self-removes once stale, instead of
        # appending into already-drained buffers of a finalized measurement.
        self._active: list = [False]
        self._nfiltered: list = [0]

    def filtered_calls(self) -> int:
        return self._nfiltered[0]

    # -- per-thread callback factory ---------------------------------------

    def _make_callback(self, measurement):
        active = self._active
        buf = measurement.thread_buffer()
        append = buf.events.append
        flush = buf.flush
        threshold = buf.flush_threshold
        events = buf.events
        regions = measurement.regions
        by_code = regions.by_code
        by_cfunc = regions.by_cfunc
        register_code = regions.register_code
        register_cfunction = regions.register_cfunction
        clock = time.perf_counter_ns
        nfiltered = self._nfiltered

        def callback(frame, event, arg):
            if not active[0]:
                sys.setprofile(None)  # stale generation: self-remove on this thread
                return
            t = clock()
            if event == "call":
                code = frame.f_code
                rid = by_code.get(code)
                if rid is None:
                    rid = register_code(code, frame)
                if rid >= 0:
                    append((EV_ENTER, rid, t, 0))
                else:
                    # Verdict-miss path: count so the governor can observe
                    # residual hook cost (recorded events are observable
                    # through the buffers; filtered ones only here).
                    nfiltered[0] += 1
            elif event == "return":
                code = frame.f_code
                rid = by_code.get(code)
                if rid is None:
                    rid = register_code(code, frame)
                if rid >= 0:
                    append((EV_EXIT, rid, t, 0))
            elif event == "c_call":
                # C events are attributed only when the *calling* region is
                # recorded: this both honors module filters transitively and
                # keeps the measurement core from instrumenting its own
                # C calls (Score-P's runtime likewise never records itself).
                code = frame.f_code
                crid = by_code.get(code)
                if crid is None:
                    crid = register_code(code, frame)
                if crid >= 0:
                    rid = by_cfunc.get(arg)
                    if rid is None:
                        rid = register_cfunction(arg)
                    if rid >= 0:
                        append((EV_C_ENTER, rid, t, 0))
            elif event in ("c_return", "c_exception"):
                code = frame.f_code
                crid = by_code.get(code)
                if crid is None:
                    crid = register_code(code, frame)
                if crid >= 0:
                    rid = by_cfunc.get(arg)
                    if rid is None:
                        rid = register_cfunction(arg)
                    if rid >= 0:
                        append((EV_C_EXIT, rid, t, 0))
            if len(events) >= threshold:
                flush()

        return callback

    def _thread_entry(self, frame, event, arg):
        # First event observed in a freshly started thread: build that
        # thread's closure, install it, and forward the current event.
        if not self._active[0]:
            sys.setprofile(None)
            return None
        callback = self._make_callback(self._measurement)
        sys.setprofile(callback)
        return callback(frame, event, arg)

    # -- lifecycle ----------------------------------------------------------

    def install(self, measurement) -> None:
        self._measurement = measurement
        self._active = [True]  # new generation for this install
        # New threads bootstrap their own closure on their first event.
        threading.setprofile(self._thread_entry)
        sys.setprofile(self._make_callback(measurement))
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._active[0] = False  # stale callbacks on other threads self-remove
        sys.setprofile(None)
        threading.setprofile(None)
        self._installed = False
