"""Instrumenter registry."""

from __future__ import annotations

from typing import Dict, Type

from .adaptive import AdaptiveInstrumenter
from .base import Instrumenter
from .monitoring import MonitoringInstrumenter
from .none import NoneInstrumenter
from .profile import ProfileInstrumenter
from .sampling import SamplingInstrumenter
from .trace import TraceInstrumenter

INSTRUMENTERS: Dict[str, Type[Instrumenter]] = {
    NoneInstrumenter.name: NoneInstrumenter,
    ProfileInstrumenter.name: ProfileInstrumenter,
    TraceInstrumenter.name: TraceInstrumenter,
    SamplingInstrumenter.name: SamplingInstrumenter,
    MonitoringInstrumenter.name: MonitoringInstrumenter,
    AdaptiveInstrumenter.name: AdaptiveInstrumenter,
}


def make_instrumenter(name: str, **kwargs) -> Instrumenter:
    """Instantiate a registered instrumenter (event source) by name —
    ``none`` / ``profile`` / ``trace`` / ``sampling`` (takes ``period=``) /
    ``monitoring`` (PEP 669, 3.12+) / ``adaptive`` (PEP 669 epoch sampler,
    3.12+, takes ``target_rate=``).  Raises ``ValueError`` naming the
    available instrumenters on an unknown name."""
    try:
        cls = INSTRUMENTERS[name]
    except KeyError:
        raise ValueError(
            f"unknown instrumenter {name!r}; available: {sorted(INSTRUMENTERS)}"
        ) from None
    return cls(**kwargs)


__all__ = [
    "Instrumenter",
    "INSTRUMENTERS",
    "make_instrumenter",
    "NoneInstrumenter",
    "ProfileInstrumenter",
    "TraceInstrumenter",
    "SamplingInstrumenter",
    "MonitoringInstrumenter",
    "AdaptiveInstrumenter",
]
