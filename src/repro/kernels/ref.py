"""Pure-jnp oracles for the Pallas kernels.

Deliberately *independent* implementations (sequential scans, naive
attention) so kernel tests compare two different algorithmic paths.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, d)
    k: jax.Array,  # (B, T, K, d)
    v: jax.Array,  # (B, T, K, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """Naive softmax attention with GQA + causal/sliding-window masking."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def rg_lru_scan_ref(a: jax.Array, bx: jax.Array, h0: Optional[jax.Array] = None) -> jax.Array:
    """Sequential reference for h_t = a_t * h_{t-1} + bx_t.  (B, S, N) fp32."""

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    init = h0 if h0 is not None else jnp.zeros_like(a[:, 0])
    _, hs = jax.lax.scan(step, init, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def ssd_scan_ref(
    x: jax.Array,  # (B, S, H, P) fp32
    dt: jax.Array,  # (B, S, H) fp32 post-softplus
    a: jax.Array,  # (H,) fp32 negative
    b_in: jax.Array,  # (B, S, G, N)
    c_in: jax.Array,  # (B, S, G, N)
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential (token-by-token) SSM recurrence — the ground-truth SSD
    semantics: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    bb = jnp.repeat(b_in, rep, axis=2)  # (B, S, H, N)
    cc = jnp.repeat(c_in, rep, axis=2)

    def step(hstate, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * a)  # (B,H)
        hstate = hstate * decay[..., None, None] + jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", hstate, ct)
        return hstate, y

    init = h0 if h0 is not None else jnp.zeros((bsz, h, p, n), x.dtype)
    final, ys = jax.lax.scan(
        step,
        init,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), bb.swapaxes(0, 1), cc.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), final
