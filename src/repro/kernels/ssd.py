"""Pallas TPU kernel for the Mamba-2 SSD chunk scan (arXiv:2405.21060).

One (batch, head) slice per grid row; chunks iterate on the sequential
minor-most grid dim with the SSM state (P, N) carried in VMEM scratch.
Per chunk, everything is dense MXU work — exactly the paper's state-space
duality: intra-chunk attention-like matmuls + low-rank inter-chunk state
passing:

    scores  = (C B^T) ⊙ decay        (L, L) lower-tri
    y_diag  = scores @ (x·dt)        (L, P)
    y_off   = (C ⊙ decay_in) @ h     (L, P)
    h'      = chunk_decay · h + (B ⊙ decay_out)^T @ (x·dt)

The GPU implementation leans on warp shuffles for the cumsum; on TPU the
cumulative sums are small (L,) vector ops and the matmuls dominate — the
kernel keeps all of them in one VMEM-resident fusion per chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scratch, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L, 1) -> squeeze
    a = a_ref[0]  # (1,) scalar decay rate for this head
    b = b_ref[0].astype(jnp.float32)  # (L, N)
    c = c_ref[0].astype(jnp.float32)  # (L, N)
    h = h_scratch[...]  # (P, N) fp32

    dt1 = dt[:, 0]  # (L,)
    log_a = dt1 * a[0]  # (L,) negative
    acs = jnp.cumsum(log_a)  # (L,)

    # intra-chunk: scores_ij = exp(acs_i - acs_j) for j <= i
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = li >= lj
    decay = jnp.where(tri, jnp.exp(acs[:, None] - acs[None, :]), 0.0)  # (L, L)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    scores = cb * decay
    xdt = x * dt1[:, None]  # (L, P)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    decay_in = jnp.exp(acs)[:, None]  # (L, 1)
    y = y + jax.lax.dot_general(
        c * decay_in, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: h' = exp(sum log_a) * h + (b * decay_out)^T @ xdt
    total = acs[-1]
    decay_out = jnp.exp(total - acs)[:, None]  # (L, 1)
    h_new = jnp.exp(total) * h + jax.lax.dot_general(
        xdt, b * decay_out, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)

    y_ref[0] = y.astype(y_ref.dtype)
    h_scratch[...] = h_new


def ssd_chunk_scan_blocked(
    x: jax.Array,  # (B, S, H, P) fp32
    dt: jax.Array,  # (B, S, H) fp32 post-softplus
    a: jax.Array,  # (H,) fp32 negative
    b_in: jax.Array,  # (B, S, G, N) fp32 (G must divide H; broadcast outside)
    c_in: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g

    # (B, H, S, ...) layouts; one (batch, head) pair per grid row.
    xt = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    bt = jnp.repeat(b_in, rep, axis=2).transpose(0, 2, 1, 3).reshape(bsz * h, s, n)
    ct = jnp.repeat(c_in, rep, axis=2).transpose(0, 2, 1, 3).reshape(bsz * h, s, n)
    ah = jnp.tile(a, bsz).reshape(bsz * h, 1)

    kernel = functools.partial(_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(bsz * h, 1, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda ib, _, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, _, ic: (ib, ic, 0)),
            pl.BlockSpec((1, 1), lambda ib, _, ic: (ib, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, _, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, _, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda ib, _, ic: (ib, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, ah, bt, ct)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
