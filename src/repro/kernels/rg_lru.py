"""Pallas TPU kernel for the RG-LRU linear recurrence (Griffin, arXiv:2402.19427).

h_t = a_t * h_{t-1} + b_t  — elementwise over the channel dim.

TPU adaptation: Griffin ships a custom GPU scan; on TPU the natural shape is
a *blocked linear scan*: grid (B, n_channel_blocks, n_time_blocks), the
channel dim rides the 128-lane VPU, and the carry h lives in VMEM scratch
across the sequential time-block dimension.  Within a block the recurrence
runs as an unrolled elementwise loop — linear work, no log-depth blowup like
``associative_scan`` (which XLA would otherwise materialize S·log S wide).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_scratch, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    h = h_scratch[0]  # (block_n,)
    a = a_ref[0]  # (block_t, block_n)
    b = b_ref[0]
    out = jnp.zeros_like(b)
    for t in range(block_t):  # unrolled: block_t is a compile-time constant
        h = a[t] * h + b[t]
        out = out.at[t].set(h)
    o_ref[0] = out
    h_scratch[0] = h


def rg_lru_scan_blocked(
    a: jax.Array,  # (B, S, N) fp32
    bx: jax.Array,  # (B, S, N) fp32
    *,
    block_t: int = 16,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bsz, s, n = a.shape
    block_t = min(block_t, s)
    block_n = min(block_n, n)
    assert s % block_t == 0 and n % block_n == 0, (s, n, block_t, block_n)
    nt, nn = s // block_t, n // block_n

    def index(ib, inn, it):
        return (ib, it, inn)

    kernel = functools.partial(_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nn, nt),  # time is minor-most: sequential, scratch carries h
        in_specs=[
            pl.BlockSpec((1, block_t, block_n), index),
            pl.BlockSpec((1, block_t, block_n), index),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_n), index),
        out_shape=jax.ShapeDtypeStruct((bsz, s, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_n), jnp.float32)],
        interpret=interpret,
    )(a, bx)
