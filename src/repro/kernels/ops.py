"""jit'd wrappers around the Pallas kernels with backend dispatch.

On CPU (this container) kernels run with ``interpret=True`` — the kernel
body executes in Python for correctness validation; on a real TPU backend
``interpret=False`` compiles to Mosaic.  The model layer calls these through
config flags (``use_flash_kernel`` / ``use_scan_kernels``).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .rg_lru import rg_lru_scan_blocked
from .ssd import ssd_chunk_scan_blocked


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # (B, S, H, d)
    k: jax.Array,  # (B, T, K, d)
    v: jax.Array,  # (B, T, K, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """FlashAttention with GQA; returns (B, S, H, d)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    out = flash_attention_bhsd(
        qb,
        kb,
        vb,
        n_q_per_kv=h // kh,
        scale=1.0 / math.sqrt(d),
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=_interpret(),
    )
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_t", "block_n"))
def rg_lru_scan(a: jax.Array, bx: jax.Array, *, block_t: int = 16, block_n: int = 128) -> jax.Array:
    """Blocked linear scan: h_t = a_t h_{t-1} + bx_t.  (B, S, N) fp32."""
    return rg_lru_scan_blocked(a, bx, block_t=block_t, block_n=block_n, interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_chunk_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_in: jax.Array,
    c_in: jax.Array,
    *,
    chunk: int = 64,
) -> Tuple[jax.Array, None]:
    """Fused SSD chunk scan; returns (y, None) — final state is kept device-
    side by the prefill path via the reference implementation."""
    y = ssd_chunk_scan_blocked(x, dt, a, b_in, c_in, chunk=chunk, interpret=_interpret())
    return y, None
