"""Pallas TPU flash attention (causal / sliding-window, GQA).

TPU adaptation of FlashAttention (arXiv:2205.14135): online-softmax
accumulation in VMEM scratch across the sequential last grid dimension
(TPU grids iterate minor-most last, so scratch persists across k-blocks),
MXU-aligned block shapes, fp32 accumulation.  Block-level pruning skips
(q-block, k-block) pairs that are fully masked (causal upper triangle,
sliding-window lower band) — the kernel is O(S*W) for window attention.

Layout: q (BH, S, d), k/v (BK, T, d); grid (BH, nq, nk).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scratch,
    l_scratch,
    acc_scratch,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    num_kb: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = iq * block_q
    k_start = ik * block_k

    relevant = jnp.asarray(True)
    if causal:
        relevant = relevant & (k_start <= q_start + block_q - 1)
    if window is not None:
        relevant = relevant & (k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok = ok & (kpos <= qpos)
        if window is not None:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scratch[:, :1]  # (bq, 1)
        l_prev = l_scratch[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        p = jnp.exp(s - m_new)  # (bq, bk); rows with no valid key ~ exp(0)=1*0-mask
        p = jnp.where(ok, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scratch[...] = jnp.broadcast_to(m_new, m_scratch.shape)
        l_scratch[...] = jnp.broadcast_to(l_new, l_scratch.shape)

    @pl.when(ik == num_kb - 1)
    def _finalize():
        l = l_scratch[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scratch[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (BH, S, d)
    k: jax.Array,  # (BK, T, d)
    v: jax.Array,  # (BK, T, d)
    *,
    n_q_per_kv: int,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, s, d = q.shape
    bk_heads, t, _ = k.shape
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    nq, nk = s // block_q, t // block_k

    def q_index(ib, iq, ik):
        return (ib, iq, 0)

    def kv_index(ib, iq, ik):
        return (ib // n_q_per_kv, ik, 0)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        num_kb=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
