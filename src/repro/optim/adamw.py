"""AdamW with decoupled weight decay + gradient clipping (pure pytree impl)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Dict[str, Any]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None  # step -> lr scale


def init(params: Params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def update(
    cfg: AdamWConfig,
    grads: Params,
    state: OptState,
    params: Params,
) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, stats)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule is not None:
        lr = lr * cfg.schedule(count)

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bias1 = 1.0 - b1**c
    bias2 = 1.0 - b2**c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bias1
        vhat = v / bias2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (
        jax.tree.unflatten(tree, new_p),
        {"m": jax.tree.unflatten(tree, new_m), "v": jax.tree.unflatten(tree, new_v), "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )


def cosine_schedule(warmup: int, total: int, min_scale: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_scale + (1 - min_scale) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return warm * cos

    return fn
