"""In-process agent runtime — what ``MeasurementConfig.agent`` turns on.

One :class:`AgentRuntime` per measurement: always an
:class:`~repro.agent.publisher.AgentPublisher` (the ring writer on the flush
path), plus — on rank 0 only — the sidecar (aggregator + HTTP server)
hosting the live endpoints.  Non-zero ranks publish their rings and rank 0's
aggregator fans them in from the sibling run dirs under ``out_dir``
(rescanned periodically, so late-starting ranks join the window when they
appear).

The measurement talks to this object through four calls: ``on_flush`` /
``on_metric`` (mirroring the substrate surface), ``take_publish_cost_ns``
(the governor's accounting pull), and ``close`` (one of finalize's isolated
best-effort hooks).
"""

from __future__ import annotations

import os
import sys
from typing import TYPE_CHECKING, Any, Dict, Optional

from .aggregator import Aggregator
from .publisher import AgentPublisher
from .serve import AgentServer

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.measurement import Measurement


class AgentRuntime:
    def __init__(self, measurement: "Measurement", announce: bool = True):
        self.measurement = measurement
        cfg = measurement.config
        self.publisher = AgentPublisher(measurement)
        self.server: Optional[AgentServer] = None
        if cfg.topology.rank == 0:
            root: Optional[str] = None
            out_dir = cfg.out_dir
            # Fan-in root: sibling rank run dirs live under out_dir; an
            # explicit run_dir outside it still gets its own ring via paths.
            if out_dir and os.path.isdir(out_dir):
                root = out_dir
            aggregator = Aggregator(
                paths=(self.publisher.ring_path,),
                root=root,
                experiment=cfg.experiment,
            )
            self.server = AgentServer(
                aggregator, port=int(cfg.agent_port or 0)
            ).start()
            if announce:
                print(
                    f"[repro.agent] live endpoint at {self.server.url} "
                    f"(ring: {self.publisher.ring_path})",
                    file=sys.stderr,
                )

    # -- measurement-facing surface ------------------------------------------

    def on_flush(self, thread_id: int, columns) -> None:
        self.publisher.on_flush(thread_id, columns)

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        self.publisher.on_metric(name, value, t_ns)

    def take_publish_cost_ns(self) -> int:
        return self.publisher.take_publish_cost_ns()

    def describe(self) -> Dict[str, Any]:
        doc = self.publisher.describe()
        if self.server is not None:
            doc["url"] = self.server.url
        return doc

    def close(self) -> None:
        if self.server is not None:
            self.server.stop()
            self.server.aggregator.close()
            self.server = None
        self.publisher.close()
