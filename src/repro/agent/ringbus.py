"""Shared-memory event ring — the live observability bus.

One mmap-backed file (``agent.ring`` in the run dir) carries flush-granular
event records out of the measured process, following the lock-free mapfile
idiom scalene uses for its sampling channel: a fixed header page with
monotonic sequence counters, then a fixed-width slot array.  Single writer
(the measured process), single reader (the sidecar aggregator); neither ever
blocks the other:

* The **writer** publishes one whole flush batch per call — a single
  vectorized copy of :data:`RECORD_DTYPE` records (the same fixed-width
  ``(kind u1, region i4, t u8, aux u4)`` encoding ``NumpyEventBuffer``
  flushes) — and bumps ``write_seq`` *after* the slots are filled.  When the
  batch does not fit in the free space it is dropped whole (never split,
  never blocked) and ``drops`` counts the lost records.
* The **reader** owns ``read_seq``: it copies ``[read_seq, write_seq)`` out
  of the slot array and advances the counter.  Because the writer never
  writes past ``read_seq + capacity``, the copied span is stable without any
  lock.  A reader that attaches (or re-attaches after a crash) snaps
  ``read_seq`` to the newest sequence — spectating starts *now*, not at a
  stale backlog.

Control records share the slot array with event records:

* ``REC_BATCH`` — batch header; ``region`` is a small per-thread stream id,
  ``aux`` the number of event records that follow.  Batches are written
  atomically under the writer lock, so a drained span always contains whole
  batches and per-batch leaf-pair analysis never sees a torn stream.
* ``REC_METRIC`` — one metric sample; ``region`` is an interned metric id,
  ``aux`` the float32 bit pattern of the value.

Region/metric ids are meaningless without the definitions sidecar
(``agent_defs.json``, written atomically next to the ring whenever the
table grows) — see :func:`write_defs` / :func:`read_defs`.

Counter stores are aligned 8-byte writes — atomic in practice on every
platform CPython's mmap supports; the monotonic-counter protocol needs no
stronger guarantee because each side only ever writes its own counter.
"""

from __future__ import annotations

import json
import mmap
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

#: Ring file + definitions sidecar names inside a run dir.
RING_FILENAME = "agent.ring"
DEFS_FILENAME = "agent_defs.json"

MAGIC = 0x52504D4F4E524E47  # "RPMONRNG"
VERSION = 1

#: Fixed-width record — the ring-slot form of ``buffer.COLUMNS``.
RECORD_DTYPE = np.dtype(
    [("kind", "u1"), ("region", "<i4"), ("t", "<u8"), ("aux", "<u4")]
)
RECORD_SIZE = RECORD_DTYPE.itemsize  # 17 bytes

#: Control record kinds (event kinds are 0..5, see repro.core.buffer.EV_*).
REC_BATCH = 240
REC_METRIC = 241

#: Header page size; slots start at this offset.
HEADER_SIZE = 4096

HEADER_DTYPE = np.dtype(
    [
        ("magic", "<u8"),
        ("version", "<u4"),
        ("record_size", "<u4"),
        ("capacity", "<u8"),
        ("write_seq", "<u8"),
        ("read_seq", "<u8"),
        ("drops", "<u8"),
        ("heartbeat_ns", "<u8"),
        ("epoch_time_ns", "<u8"),
        ("epoch_perf_ns", "<u8"),
        ("rank", "<u4"),
        ("pid", "<u4"),
        ("writer_closed", "<u4"),
    ]
)

#: Default slot count (records).  ~2.2 MB at 17 B/record; the measurement
#: sizes its ring to hold at least two flush batches (see publisher).
DEFAULT_CAPACITY = 1 << 17


class RingError(RuntimeError):
    """Missing, truncated, or incompatible ring file."""


def encode_columns(columns: Dict[str, np.ndarray], stream: int = 0) -> np.ndarray:
    """One flush batch -> ``REC_BATCH`` header + its event records.

    Four vectorized column assignments; no per-event Python.
    """
    n = int(len(columns["kind"]))
    rec = np.empty(n + 1, dtype=RECORD_DTYPE)
    rec[0] = (REC_BATCH, stream, time.perf_counter_ns(), n)
    body = rec[1:]
    body["kind"] = columns["kind"]
    body["region"] = columns["region"]
    body["t"] = columns["t"]
    body["aux"] = columns["aux"]
    return rec


def encode_metric(metric_id: int, value: float, t_ns: int) -> np.ndarray:
    """One metric sample as a single control record (value as f32 bits)."""
    rec = np.empty(1, dtype=RECORD_DTYPE)
    bits = int(np.float32(value).view(np.uint32))
    rec[0] = (REC_METRIC, metric_id, t_ns, bits)
    return rec


def decode_records(
    rec: np.ndarray,
) -> Tuple[List[Tuple[int, Dict[str, np.ndarray]]], List[Tuple[int, int, float]]]:
    """Split a drained span back into flush batches and metric samples.

    Returns ``(batches, metrics)`` where each batch is ``(stream_id,
    columns)`` with the same column dict shape substrates receive, and each
    metric is ``(metric_id, t_ns, value)``.  Stray event records without a
    batch header (a batch whose header slot was dropped can't occur — drops
    are whole-batch — but a half-written tail could appear if a writer died
    mid-copy) are skipped rather than misattributed.
    """
    batches: List[Tuple[int, Dict[str, np.ndarray]]] = []
    metrics: List[Tuple[int, int, float]] = []
    kinds = rec["kind"]
    i, n = 0, len(rec)
    while i < n:
        k = int(kinds[i])
        if k == REC_BATCH:
            cnt = int(rec["aux"][i])
            body = rec[i + 1 : i + 1 + cnt]
            if len(body) == cnt:
                batches.append(
                    (
                        int(rec["region"][i]),
                        {
                            "kind": body["kind"].copy(),
                            "region": body["region"].copy(),
                            "t": body["t"].copy(),
                            "aux": body["aux"].copy(),
                        },
                    )
                )
            i += 1 + cnt
        elif k == REC_METRIC:
            bits = np.uint32(rec["aux"][i])
            metrics.append(
                (int(rec["region"][i]), int(rec["t"][i]), float(bits.view(np.float32)))
            )
            i += 1
        else:
            i += 1
    return batches, metrics


class _Ring:
    """Shared mmap plumbing for writer and reader."""

    def __init__(self):
        self._mm: Optional[mmap.mmap] = None
        self._file = None
        self._hdr: Optional[np.ndarray] = None
        self._slots: Optional[np.ndarray] = None
        self.path = ""
        self.capacity = 0

    def _map(self, fileobj, capacity: int) -> None:
        self._file = fileobj
        self._mm = mmap.mmap(fileobj.fileno(), HEADER_SIZE + capacity * RECORD_SIZE)
        self._hdr = np.frombuffer(self._mm, dtype=HEADER_DTYPE, count=1)
        self._slots = np.frombuffer(
            self._mm, dtype=RECORD_DTYPE, count=capacity, offset=HEADER_SIZE
        )
        self.capacity = capacity

    def _field(self, name: str) -> int:
        return int(self._hdr[name][0])

    @property
    def write_seq(self) -> int:
        return self._field("write_seq")

    @property
    def read_seq(self) -> int:
        return self._field("read_seq")

    @property
    def drops(self) -> int:
        return self._field("drops")

    @property
    def lag(self) -> int:
        return self.write_seq - self.read_seq

    @property
    def heartbeat_ns(self) -> int:
        return self._field("heartbeat_ns")

    @property
    def rank(self) -> int:
        return self._field("rank")

    @property
    def epoch_time_ns(self) -> int:
        return self._field("epoch_time_ns")

    @property
    def epoch_perf_ns(self) -> int:
        return self._field("epoch_perf_ns")

    @property
    def writer_closed(self) -> bool:
        return bool(self._field("writer_closed"))

    def close(self) -> None:
        # Release the numpy views before the mmap: frombuffer views keep
        # exported pointers that make mmap.close() raise BufferError.
        self._hdr = None
        self._slots = None
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        if self._file is not None:
            self._file.close()
            self._file = None


class RingWriter(_Ring):
    """Single-writer end: creates the ring file and publishes batches.

    Thread-safe (flushes arrive from any thread; metric samples from user
    threads): a small lock serializes the batch copy + counter bump, which
    also guarantees batch atomicity for the reader's parser.
    """

    def __init__(
        self,
        path: str,
        capacity: int = DEFAULT_CAPACITY,
        *,
        rank: int = 0,
        epoch_time_ns: int = 0,
        epoch_perf_ns: int = 0,
    ):
        super().__init__()
        if capacity <= 1:
            raise ValueError("ring capacity must be > 1 record")
        self.path = path
        self._lock = threading.Lock()
        fh = open(path, "w+b")
        fh.truncate(HEADER_SIZE + capacity * RECORD_SIZE)
        self._map(fh, capacity)
        hdr = self._hdr
        hdr["version"][0] = VERSION
        hdr["record_size"][0] = RECORD_SIZE
        hdr["capacity"][0] = capacity
        hdr["rank"][0] = rank
        hdr["pid"][0] = os.getpid() & 0xFFFFFFFF
        hdr["epoch_time_ns"][0] = epoch_time_ns or time.time_ns()
        hdr["epoch_perf_ns"][0] = epoch_perf_ns or time.perf_counter_ns()
        hdr["heartbeat_ns"][0] = time.time_ns()
        # Magic last: a reader racing creation sees zero magic -> not a ring
        # yet, rather than a ring with garbage geometry.
        hdr["magic"][0] = MAGIC

    def publish(self, records: np.ndarray) -> bool:
        """Copy ``records`` into the ring; False when dropped on overrun."""
        n = len(records)
        if n == 0:
            return True
        with self._lock:
            hdr = self._hdr
            w = int(hdr["write_seq"][0])
            free = self.capacity - (w - int(hdr["read_seq"][0]))
            if n > free:
                hdr["drops"][0] += n
                hdr["heartbeat_ns"][0] = time.time_ns()
                return False
            start = w % self.capacity
            end = start + n
            if end <= self.capacity:
                self._slots[start:end] = records
            else:
                split = self.capacity - start
                self._slots[start:] = records[:split]
                self._slots[: end - self.capacity] = records[split:]
            hdr["write_seq"][0] = w + n
            hdr["heartbeat_ns"][0] = time.time_ns()
            return True

    def close(self) -> None:
        if self._hdr is not None:
            self._hdr["writer_closed"][0] = 1
            self._mm.flush()
        super().close()


class RingReader(_Ring):
    """Single-reader end: attaches to an existing ring and drains it.

    Attaching snaps ``read_seq`` to the current ``write_seq`` — a reader
    always resumes at the newest sequence (crash-and-reattach semantics),
    never replays a backlog it wasn't watching.  One reader at a time: a
    second attach steals the cursor from the first.
    """

    def __init__(self, path: str):
        super().__init__()
        self.path = path
        if not os.path.exists(path):
            raise RingError(f"no ring at {path}")
        size = os.path.getsize(path)
        if size < HEADER_SIZE:
            raise RingError(f"{path} is not a ring (truncated header: {size} bytes)")
        fh = open(path, "r+b")
        try:
            hdr = np.frombuffer(
                fh.read(HEADER_SIZE), dtype=HEADER_DTYPE, count=1
            )
            if int(hdr["magic"][0]) != MAGIC:
                raise RingError(f"{path} is not a ring (bad magic)")
            if int(hdr["version"][0]) != VERSION:
                raise RingError(
                    f"{path} is ring version {int(hdr['version'][0])}, "
                    f"this reader speaks {VERSION}"
                )
            if int(hdr["record_size"][0]) != RECORD_SIZE:
                raise RingError(
                    f"{path} has {int(hdr['record_size'][0])}-byte records, "
                    f"expected {RECORD_SIZE}"
                )
            capacity = int(hdr["capacity"][0])
            if size < HEADER_SIZE + capacity * RECORD_SIZE:
                raise RingError(f"{path} is truncated (capacity {capacity})")
        except RingError:
            fh.close()
            raise
        self._map(fh, capacity)
        # Resume at the newest sequence.
        self._hdr["read_seq"][0] = self._hdr["write_seq"][0]

    def poll(self) -> np.ndarray:
        """Copy out everything published since the last poll and advance."""
        hdr = self._hdr
        w = int(hdr["write_seq"][0])
        r = int(hdr["read_seq"][0])
        n = w - r
        if n <= 0:
            return np.empty(0, dtype=RECORD_DTYPE)
        start = r % self.capacity
        end = start + n
        if end <= self.capacity:
            out = self._slots[start:end].copy()
        else:
            out = np.concatenate(
                [self._slots[start:], self._slots[: end - self.capacity]]
            )
        hdr["read_seq"][0] = w
        return out

    @property
    def heartbeat_age_s(self) -> float:
        return max(time.time_ns() - self.heartbeat_ns, 0) / 1e9


# -- definitions sidecar ------------------------------------------------------


def defs_path_for(ring_path: str) -> str:
    return os.path.join(os.path.dirname(ring_path) or ".", DEFS_FILENAME)


def write_defs(path: str, doc: Dict[str, Any]) -> None:
    """Atomic write (tmp + rename): the reader never sees a torn JSON."""
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=1)
    os.replace(tmp, path)


def read_defs(path: str) -> Optional[Dict[str, Any]]:
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None
