"""Sidecar aggregator — rolling-window stats over one or more rings.

The aggregator tails :class:`~repro.agent.ringbus.RingReader` streams and
maintains a *rolling window* (default 60 s, split into 12 time buckets that
expire as wall time advances) of per-region statistics:

* **visit counts** — enters per region, vectorized per drained batch;
* **exclusive-time streaming moments** — sum / sum-of-squares / min / max of
  *leaf* enter→exit pair durations (the same vectorizable leaf-pair
  exclusive-time estimate the governor uses: the hot, short regions a live
  view is watching for are exactly leaf pairs);
* **reservoir-sampled durations** — a bounded per-region/per-bucket sample
  of leaf durations, merged at snapshot time into window percentiles
  (p50/p95) without ever storing the full stream;

plus the latest ``mem.*`` / metric series points (bounded, window-pruned).

Multi-rank fan-in follows ``merge_runs`` semantics: the aggregator ingests N
rings from sibling rank run dirs (periodic rescan of a root directory picks
up late-starting ranks), aligns each ring's ``perf_counter`` timestamps onto
the shared wall clock via its header epoch pair (``offset_ns = epoch_time_ns
- epoch_perf_ns``), and when two rings claim the same rank keeps the one
with the newest epoch, dropping the stale duplicate (restarted process wins,
exactly like ``merge._dedupe_ranks``).

:meth:`Aggregator.snapshot` emits the *report model* document shape
(``build_report``'s layout, schema-stamped) so ``core/report``'s renderer
serves the live window unchanged; the extra ``window`` section carries ring
health (lag, drops, heartbeat age) and windowing parameters, and doubles as
the ``/healthz`` payload.
"""

from __future__ import annotations

import math
import os
import random
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.buffer import EV_C_ENTER, EV_ENTER
from repro.core.report.model import decimate
from repro.core.schema import stamp

from .ringbus import (
    RING_FILENAME,
    RingError,
    RingReader,
    decode_records,
    defs_path_for,
    read_defs,
)

DEFAULT_WINDOW_S = 60.0
DEFAULT_BUCKETS = 12

#: Reservoir capacity per (bucket, rank, region).
RESERVOIR_K = 32

#: Ring-health thresholds for the /healthz status verdict.
STALE_HEARTBEAT_S = 30.0

#: Per-series point cap while accumulating (pruned to the window anyway).
MAX_SERIES_POINTS = 4096


class RingTail:
    """One ring + its definitions sidecar, with id -> name resolution."""

    def __init__(self, path: str):
        self.path = path
        self.reader = RingReader(path)
        self._regions: Dict[int, Tuple[str, Optional[str]]] = {}
        self._metrics: Dict[int, str] = {}
        self.meta: Dict[str, Any] = {}
        self.events = 0
        self.batches = 0
        self._reload_t = 0.0
        self._load_defs()

    def _load_defs(self) -> None:
        doc = read_defs(defs_path_for(self.path))
        if not doc:
            return
        self.meta = doc.get("meta") or {}
        for row in doc.get("regions") or []:
            self._regions[int(row[0])] = (str(row[1]), row[2])
        for name, mid in (doc.get("metrics") or {}).items():
            self._metrics[int(mid)] = str(name)

    @property
    def rank(self) -> int:
        return int(self.meta.get("rank", self.reader.rank))

    @property
    def epoch_time_ns(self) -> int:
        return self.reader.epoch_time_ns

    @property
    def offset_ns(self) -> int:
        """perf-clock -> wall-clock alignment, as in ``merge_runs``."""
        return self.reader.epoch_time_ns - self.reader.epoch_perf_ns

    def _maybe_reload(self) -> None:
        # The writer rewrites the sidecar (throttled) as its tables grow, so
        # an unknown id usually means "defs are momentarily behind" — reload,
        # but never cache the placeholder: the next reload heals the name.
        now = time.monotonic()
        if now - self._reload_t >= 0.25:
            self._reload_t = now
            self._load_defs()

    def region_name(self, rid: int) -> Tuple[str, Optional[str]]:
        entry = self._regions.get(rid)
        if entry is None:
            self._maybe_reload()
            entry = self._regions.get(rid)
        return entry or (f"region#{rid}", None)

    def metric_name(self, mid: int) -> str:
        name = self._metrics.get(mid)
        if name is None:
            self._maybe_reload()
            name = self._metrics.get(mid)
        return name or f"metric#{mid}"

    def health(self) -> Dict[str, Any]:
        r = self.reader
        return {
            "ring": self.path,
            "rank": self.rank,
            "lag": r.lag,
            "drops": r.drops,
            "write_seq": r.write_seq,
            "heartbeat_age_s": round(r.heartbeat_age_s, 3),
            "writer_closed": r.writer_closed,
            "events": self.events,
            "batches": self.batches,
        }

    def close(self) -> None:
        self.reader.close()


def _new_stat(kind: Optional[str]) -> Dict[str, Any]:
    return {
        "kind": kind,
        "visits": 0,
        "n": 0,
        "sum": 0.0,
        "sum2": 0.0,
        "min": math.inf,
        "max": 0.0,
        "seen": 0,
        "res": [],
    }


def _reservoir_merge(stat: Dict[str, Any], dur: np.ndarray, k: int) -> None:
    """Fold a batch of durations into the bounded reservoir.

    Two-level approximation of Algorithm R (documented, deliberate): large
    batches are first down-sampled to ``k`` candidates, then each candidate
    displaces a random slot with probability ``m / (seen + m)``.  Work per
    batch is O(k), independent of the batch size.
    """
    m = int(dur.size)
    if m == 0:
        return
    if m > k:
        cand = dur[np.random.choice(m, size=k, replace=False)]
    else:
        cand = dur
    res = stat["res"]
    seen = stat["seen"]
    p = m / max(seen + m, 1)
    for v in cand.tolist():
        if len(res) < k:
            res.append(v)
        elif random.random() < p:
            res[random.randrange(k)] = v
    stat["seen"] = seen + m


class Aggregator:
    """Rolling-window fan-in over N rings; snapshot = live report doc."""

    def __init__(
        self,
        paths: Tuple[str, ...] = (),
        *,
        root: Optional[str] = None,
        experiment: Optional[str] = None,
        window_s: float = DEFAULT_WINDOW_S,
        buckets: int = DEFAULT_BUCKETS,
        rescan_s: float = 2.0,
        reservoir_k: int = RESERVOIR_K,
    ):
        self.window_s = float(window_s)
        self.n_buckets = max(int(buckets), 1)
        self._bucket_ns = int(self.window_s / self.n_buckets * 1e9)
        self.root = root
        self.experiment = experiment
        self.rescan_s = float(rescan_s)
        self.reservoir_k = int(reservoir_k)
        self._lock = threading.RLock()
        self._tails: Dict[str, RingTail] = {}
        self._dropped_rings: List[Dict[str, Any]] = []
        self._seen_paths: set = set()
        #: time buckets, oldest first: {"t0": wall_ns, "stats": {(rank, name): stat}}
        self._buckets: List[Dict[str, Any]] = []
        #: metric series keyed (rank, metric id) -> [[wall_ns, value], ...]
        self._series: Dict[Tuple[int, int], List[List[float]]] = {}
        self._last_scan = 0.0
        self.total_events = 0
        self.total_batches = 0
        for p in paths:
            self._attach(p)  # explicit paths must be valid: raises RingError
        if root is not None:
            self._scan()
        if not self._tails and root is None:
            raise RingError("aggregator needs at least one ring path or a root")

    # -- ring set management (merge_runs semantics) --------------------------

    def _attach(self, path: str) -> None:
        path = os.path.abspath(path)
        if path in self._seen_paths:
            return
        tail = RingTail(path)
        self._seen_paths.add(path)
        for other_path, other in list(self._tails.items()):
            if other.rank == tail.rank:
                # Same rank twice: the newest epoch wins (a restarted rank
                # supersedes its stale ring), mirroring merge._dedupe_ranks.
                if tail.epoch_time_ns >= other.epoch_time_ns:
                    self._dropped_rings.append(
                        {"run_dir": os.path.dirname(other_path), "rank": other.rank}
                    )
                    other.close()
                    del self._tails[other_path]
                else:
                    self._dropped_rings.append(
                        {"run_dir": os.path.dirname(path), "rank": tail.rank}
                    )
                    tail.close()
                    return
        self._tails[path] = tail

    def _scan(self) -> None:
        root = self.root
        if root is None or not os.path.isdir(root):
            return
        candidates = [os.path.join(root, RING_FILENAME)]
        try:
            entries = sorted(os.scandir(root), key=lambda e: e.name)
        except OSError:
            entries = []
        for entry in entries:
            if not entry.is_dir():
                continue
            name = entry.name
            if self.experiment is not None and not (
                name == self.experiment or name.startswith(self.experiment + "-")
            ):
                continue
            candidates.append(os.path.join(entry.path, RING_FILENAME))
        for ring in candidates:
            if ring not in self._seen_paths and os.path.exists(ring):
                try:
                    self._attach(ring)
                except RingError:
                    pass  # mid-creation or foreign file; next rescan retries

    # -- ingestion -----------------------------------------------------------

    def drain_once(self) -> int:
        """Poll every ring once, folding everything new into the window."""
        with self._lock:
            now = time.monotonic()
            if self.root is not None and now - self._last_scan >= self.rescan_s:
                self._last_scan = now
                self._scan()
            drained = 0
            for tail in self._tails.values():
                rec = tail.reader.poll()
                if not len(rec):
                    continue
                drained += len(rec)
                batches, metrics = decode_records(rec)
                wall = time.time_ns()
                stats = self._bucket(wall)["stats"]
                for _stream, columns in batches:
                    self._ingest_batch(tail, columns, stats)
                for mid, t_ns, value in metrics:
                    self._ingest_metric(tail, mid, t_ns, value)
            return drained

    def _bucket(self, wall_ns: int) -> Dict[str, Any]:
        buckets = self._buckets
        if not buckets or wall_ns - buckets[-1]["t0"] >= self._bucket_ns:
            buckets.append({"t0": wall_ns, "stats": {}})
            self._prune(wall_ns)
        return buckets[-1]

    def _prune(self, wall_ns: int) -> None:
        horizon = wall_ns - int(self.window_s * 1e9) - self._bucket_ns
        while self._buckets and self._buckets[0]["t0"] < horizon:
            self._buckets.pop(0)
        cutoff = wall_ns - int(self.window_s * 1e9)
        for name, pts in list(self._series.items()):
            if len(pts) > MAX_SERIES_POINTS or (pts and pts[0][0] < cutoff):
                self._series[name] = [p for p in pts if p[0] >= cutoff][
                    -MAX_SERIES_POINTS:
                ]

    def _ingest_batch(
        self, tail: RingTail, columns: Dict[str, np.ndarray], stats: Dict
    ) -> None:
        kind = columns["kind"]
        region = columns["region"]
        t = columns["t"]
        n = int(kind.size)
        if not n:
            return
        tail.events += n
        tail.batches += 1
        self.total_events += n
        self.total_batches += 1
        rank = tail.rank
        enter_mask = (kind == EV_ENTER) | (kind == EV_C_ENTER)
        enters = region[enter_mask]
        if enters.size:
            ids, counts = np.unique(enters, return_counts=True)
            for rid, c in zip(ids.tolist(), counts.tolist()):
                # Stats are keyed by raw region id; names resolve lazily at
                # snapshot time, after the writer's defs sidecar caught up.
                key = (rank, int(rid))
                stat = stats.get(key)
                if stat is None:
                    stat = stats[key] = _new_stat(None)
                stat["visits"] += int(c)
        if n > 1:
            # Leaf pairs (enter immediately followed by its matching exit):
            # pure exclusive time, vectorizable — same estimate the governor
            # accounts with; pairs spanning a flush boundary are lost (the
            # documented approximation).
            leaf = (
                enter_mask[:-1]
                & (kind[1:] == kind[:-1] + 1)
                & (region[1:] == region[:-1])
            )
            if leaf.any():
                dur = (t[1:][leaf] - t[:-1][leaf]).astype(np.float64)
                leaf_regs = region[:-1][leaf]
                for rid in np.unique(leaf_regs).tolist():
                    d = dur[leaf_regs == rid]
                    key = (rank, int(rid))
                    stat = stats.get(key)
                    if stat is None:
                        stat = stats[key] = _new_stat(None)
                    stat["n"] += int(d.size)
                    stat["sum"] += float(d.sum())
                    stat["sum2"] += float(np.dot(d, d))
                    stat["min"] = min(stat["min"], float(d.min()))
                    stat["max"] = max(stat["max"], float(d.max()))
                    _reservoir_merge(stat, d, self.reservoir_k)

    def _ingest_metric(self, tail: RingTail, mid: int, t_ns: int, value: float) -> None:
        # Keyed by (rank, metric id) — like region stats, names resolve at
        # snapshot time so early samples aren't stuck under a placeholder.
        wall = tail.offset_ns + t_ns
        self._series.setdefault((tail.rank, mid), []).append([wall, value])

    # -- snapshots -----------------------------------------------------------

    @staticmethod
    def _merge_into(acc: Dict[str, Any], stat: Dict[str, Any]) -> None:
        acc["kind"] = acc["kind"] or stat["kind"]
        acc["visits"] += stat["visits"]
        acc["n"] += stat["n"]
        acc["sum"] += stat["sum"]
        acc["sum2"] += stat["sum2"]
        acc["min"] = min(acc["min"], stat["min"])
        acc["max"] = max(acc["max"], stat["max"])
        acc["seen"] += stat["seen"]
        acc["res"].extend(stat["res"])

    def _merged_stats(self) -> Dict[Tuple[int, str], Dict[str, Any]]:
        """Window stats merged across buckets, then resolved to names:
        (rank, region_id) accumulators become (rank, region_name)."""
        by_id: Dict[Tuple[int, int], Dict[str, Any]] = {}
        for bucket in self._buckets:
            for key, stat in bucket["stats"].items():
                acc = by_id.get(key)
                if acc is None:
                    acc = by_id[key] = _new_stat(stat["kind"])
                self._merge_into(acc, stat)
        rank_tails = {t.rank: t for t in self._tails.values()}
        merged: Dict[Tuple[int, str], Dict[str, Any]] = {}
        for (rank, rid), stat in by_id.items():
            tail = rank_tails.get(rank)
            if tail is not None:
                name, rkind = tail.region_name(rid)
            else:  # tail replaced/dropped mid-window: keep the stats visible
                name, rkind = f"region#{rid}", None
            stat["kind"] = stat["kind"] or rkind
            acc = merged.get((rank, name))
            if acc is None:
                merged[(rank, name)] = stat
            else:
                self._merge_into(acc, stat)
        return merged

    def snapshot(self) -> Dict[str, Any]:
        """The live window as a schema-stamped report-model document."""
        with self._lock:
            now_wall = time.time_ns()
            self._prune(now_wall)
            per_rank = self._merged_stats()
            # Collapse ranks into the unified per-region table.
            regions: Dict[str, Dict[str, Any]] = {}
            for (rank, name), stat in per_rank.items():
                acc = regions.get(name)
                if acc is None:
                    acc = regions[name] = _new_stat(stat["kind"])
                acc["kind"] = acc["kind"] or stat["kind"]
                for field in ("visits", "n", "sum", "sum2", "seen"):
                    acc[field] += stat[field]
                acc["min"] = min(acc["min"], stat["min"])
                acc["max"] = max(acc["max"], stat["max"])
                acc["res"].extend(stat["res"])
            rows = []
            for name, acc in regions.items():
                excl = int(acc["sum"])
                visits = int(acc["visits"])
                n = int(acc["n"])
                mean = acc["sum"] / n if n else 0.0
                var = max(acc["sum2"] / n - mean * mean, 0.0) if n else 0.0
                res = sorted(acc["res"])
                rows.append(
                    {
                        "region": name,
                        "kind": acc["kind"],
                        "visits": visits,
                        # Live window: inclusive time is not tracked (no
                        # shadow-stack replay online); the leaf-pair
                        # exclusive estimate stands in for both columns.
                        "incl_ns": excl,
                        "excl_ns": excl,
                        "mean_ns": round(excl / visits, 1) if visits else None,
                        "alloc_bytes": None,
                        "net_bytes": None,
                        "alloc_blocks": None,
                        "governor_excluded": None,
                        "est_cost_ns": None,
                        "leaf_pairs": n,
                        "std_ns": round(math.sqrt(var), 1),
                        "min_ns": int(acc["min"]) if n else None,
                        "max_ns": int(acc["max"]) if n else None,
                        "p50_ns": int(res[len(res) // 2]) if res else None,
                        "p95_ns": int(res[int(len(res) * 0.95)]) if res else None,
                        "rate_per_s": round(visits / self.window_s, 2),
                    }
                )
            rows.sort(key=lambda r: -r["excl_ns"])
            cutoff = now_wall - int(self.window_s * 1e9)
            rank_tails = {t.rank: t for t in self._tails.values()}
            named_series: Dict[str, List[List[float]]] = {}
            for (rank, mid), pts in self._series.items():
                tail = rank_tails.get(rank)
                name = tail.metric_name(mid) if tail is not None else f"metric#{mid}"
                named_series.setdefault(name, []).extend(pts)
            timelines = {}
            metrics = {}
            for name, pts in sorted(named_series.items()):
                pts.sort(key=lambda p: p[0])
                live = [p for p in pts if p[0] >= cutoff]
                if not live:
                    continue
                timelines[name] = decimate(live)
                vals = [v for _, v in live if v is not None and math.isfinite(v)]
                if vals:
                    metrics[name] = {
                        "count": len(vals),
                        "mean": sum(vals) / len(vals),
                        "min": min(vals),
                        "max": max(vals),
                        "last": vals[-1],
                    }
            tails = sorted(self._tails.values(), key=lambda t: t.rank)
            meta = dict(tails[0].meta) if tails else {}
            meta.update(
                {
                    "live": True,
                    "window_s": self.window_s,
                    "world_size": len(tails) or 1,
                }
            )
            doc = {
                "run_dir": self.root
                or (os.path.dirname(tails[0].path) if tails else ""),
                "generated_time_ns": now_wall,
                "meta": meta,
                "regions": rows,
                "memory": None,
                "metrics": metrics or None,
                "timelines": timelines,
                "governor": None,
                "merge": self._merge_section(per_rank, tails),
                "plan": None,
                "diff": None,
                "window": self.healthz(),
            }
            return stamp(doc)

    def _merge_section(
        self, per_rank: Dict, tails: List[RingTail]
    ) -> Optional[Dict[str, Any]]:
        """Cross-rank view in merged_trace_summary.json's shape (rendered by
        the existing report renderer's heatmap) — only for real fan-in."""
        if len(tails) < 2:
            return None
        ranks = sorted({t.rank for t in tails})
        names = sorted(
            {name for (_r, name) in per_rank},
            key=lambda nm: -sum(
                per_rank.get((r, nm), {"sum": 0.0})["sum"] for r in ranks
            ),
        )[:20]
        excl = [
            [float(per_rank.get((r, nm), {"sum": 0.0})["sum"]) for r in ranks]
            for nm in names
        ]
        imbalance = {}
        for nm, row in zip(names, excl):
            mean = sum(row) / len(row)
            if mean > 0:
                imbalance[nm] = round(max(row) / mean, 3)
        return {
            "world_size": len(tails),
            "total_events": self.total_events,
            "ranks": [
                {
                    "rank": t.rank,
                    "events": t.events,
                    "run_dir": os.path.dirname(t.path),
                    "offset_ns": t.offset_ns,
                }
                for t in tails
            ],
            "dropped_runs": list(self._dropped_rings),
            "profile": {
                "ranks": ranks,
                "regions": names,
                "excl_ns": excl,
                "imbalance": imbalance,
            },
        }

    def healthz(self) -> Dict[str, Any]:
        with self._lock:
            rings = [t.health() for t in sorted(self._tails.values(), key=lambda t: t.rank)]
            drops = sum(r["drops"] for r in rings)
            lag = sum(r["lag"] for r in rings)
            live = [r for r in rings if not r["writer_closed"]]
            stale = [r for r in live if r["heartbeat_age_s"] > STALE_HEARTBEAT_S]
            status = "ok"
            if not rings or stale:
                status = "stale"
            elif drops:
                status = "degraded"
            return {
                "status": status,
                "time_ns": time.time_ns(),
                "window_s": self.window_s,
                "buckets": self.n_buckets,
                "events": self.total_events,
                "batches": self.total_batches,
                "drops": drops,
                "lag": lag,
                "rings": rings,
                "dropped_rings": list(self._dropped_rings),
            }

    def close(self) -> None:
        with self._lock:
            for tail in self._tails.values():
                tail.close()
            self._tails.clear()
