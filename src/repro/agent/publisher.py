"""Measurement-side half of the agent: publish flushes into the ring.

The :class:`AgentPublisher` hangs off a live :class:`~repro.core.measurement.
Measurement` (created by ``repro.agent.runtime.AgentRuntime`` when
``MeasurementConfig.agent`` is set) and mirrors the substrate surface the
flush path already fans out to — ``on_flush(thread_id, columns)`` under the
measurement flush lock, ``on_metric(name, value, t_ns)`` from user threads —
but instead of writing artifacts it forwards everything into the shared
-memory ring (:mod:`repro.agent.ringbus`) for a sidecar aggregator to tail.

Cost discipline (the governor contract):

* Every publish is timed; the cumulative nanoseconds are exposed two ways —
  ``publish_ns`` (monotonic total, for benchmarks) and
  :meth:`take_publish_cost_ns` (delta since last call), which the governor
  pulls into its window cost at each flush so live publishing is accounted
  against the same overhead budget as instrumentation itself.
* When the publish fraction of wall time exceeds its share of the budget
  (a quarter of the governor budget, or of 1% when no governor runs), the
  publisher *degrades instead of busting the budget*: it doubles its batch
  stride — publishing every 2nd, 4th, ... 64th flush batch and counting the
  thinned records — and relaxes the stride again once the pressure is gone.
  Thinning whole batches (never splitting one) keeps every published batch
  self-contained for the aggregator's per-batch leaf-pair analysis.

The publisher also keeps the definitions sidecar current (region + metric
id tables, rewritten atomically when they grow) and piggybacks a 1 Hz
``mem.rss_mb`` sample onto the publish path so the live window has a memory
series even when the memory substrate is off.
"""

from __future__ import annotations

import os
import threading
import time
from typing import TYPE_CHECKING, Any, Dict, Optional

from repro.core.memsys import rss_bytes

from .ringbus import (
    DEFAULT_CAPACITY,
    RING_FILENAME,
    RingWriter,
    defs_path_for,
    encode_columns,
    encode_metric,
    write_defs,
)

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.measurement import Measurement

#: Degradation ladder ceiling: publish at most every 64th batch.
MAX_STRIDE = 64

#: Publish-fraction controller period (ns) and budget share.
ADJUST_PERIOD_NS = int(1e9)
BUDGET_SHARE = 0.25

#: Default budget when no governor runs — the <1% publish-overhead claim.
DEFAULT_BUDGET = 0.01

#: Definitions sidecar rewrite throttle (ns) and mem-sample period (ns).
DEFS_PERIOD_NS = int(0.5e9)
MEM_PERIOD_NS = int(1e9)


class AgentPublisher:
    def __init__(
        self,
        measurement: "Measurement",
        ring_path: Optional[str] = None,
        capacity: Optional[int] = None,
    ):
        self.measurement = measurement
        cfg = measurement.config
        self.ring_path = ring_path or os.path.join(measurement.run_dir, RING_FILENAME)
        if capacity is None:
            # Room for two full flush batches (+ headers) so one slow drain
            # tick never forces a drop.
            capacity = max(DEFAULT_CAPACITY, 2 * (cfg.flush_threshold + 1) + 64)
        self.writer = RingWriter(
            self.ring_path,
            capacity,
            rank=cfg.topology.rank,
            epoch_time_ns=measurement.epoch_time_ns,
            epoch_perf_ns=measurement.epoch_perf_ns,
        )
        self.budget = float(cfg.budget) if cfg.budget > 0 else DEFAULT_BUDGET

        self._streams: Dict[int, int] = {}
        self._metric_ids: Dict[str, int] = {}
        self._metric_lock = threading.Lock()
        self._defs_regions = -1
        self._defs_metrics = -1
        self._defs_t = 0

        self.publish_ns = 0
        self._cost_pending = 0
        self._cost_lock = threading.Lock()

        self.stride = 1
        self.thinned_batches = 0
        self.thinned_records = 0
        #: Controller period — instance attribute so benchmarks/tests can
        #: shrink it to reach the governed steady state quickly.
        self.adjust_period_ns = ADJUST_PERIOD_NS
        self._batch_counter = 0
        now = time.perf_counter_ns()
        self._window_t0 = now
        self._window_publish_ns = 0
        self._mem_t = now
        self.closed = False
        self._write_defs(now)

    # -- flush-path hooks (on_flush under the measurement flush lock) --------

    def on_flush(self, thread_id: int, columns: Dict[str, Any]) -> None:
        if self.closed:
            return
        t0 = time.perf_counter_ns()
        self._batch_counter += 1
        if self.stride > 1 and (self._batch_counter % self.stride):
            self.thinned_batches += 1
            self.thinned_records += int(len(columns["kind"]))
        else:
            stream = self._streams.get(thread_id)
            if stream is None:
                stream = self._streams[thread_id] = len(self._streams)
            self.writer.publish(encode_columns(columns, stream=stream))
            self._maybe_write_defs(t0)
            self._maybe_sample_memory(t0)
        dt = time.perf_counter_ns() - t0
        self.publish_ns += dt
        self._window_publish_ns += dt
        with self._cost_lock:
            self._cost_pending += dt
        self._maybe_adjust(t0 + dt)

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        if self.closed:
            return
        t0 = time.perf_counter_ns()
        with self._metric_lock:
            mid = self._metric_ids.get(name)
            if mid is None:
                mid = self._metric_ids[name] = len(self._metric_ids)
        self.writer.publish(encode_metric(mid, value, t_ns))
        self._maybe_write_defs(t0)
        dt = time.perf_counter_ns() - t0
        self.publish_ns += dt
        with self._cost_lock:
            self._cost_pending += dt

    # -- governor integration -------------------------------------------------

    def take_publish_cost_ns(self) -> int:
        """Publish nanoseconds accrued since the last call (governor pulls
        this into its window cost at each flush)."""
        with self._cost_lock:
            pending, self._cost_pending = self._cost_pending, 0
        return pending

    def _maybe_adjust(self, now: int) -> None:
        elapsed = now - self._window_t0
        if elapsed < self.adjust_period_ns:
            return
        fraction = self._window_publish_ns / max(elapsed, 1)
        share = BUDGET_SHARE * self.budget
        if fraction > share and self.stride < MAX_STRIDE:
            self.stride = min(self.stride * 2, MAX_STRIDE)
        elif fraction < share / 4 and self.stride > 1:
            self.stride //= 2
        self._window_t0 = now
        self._window_publish_ns = 0

    # -- sidecar upkeep -------------------------------------------------------

    def _maybe_sample_memory(self, now: int) -> None:
        if now - self._mem_t < MEM_PERIOD_NS:
            return
        self._mem_t = now
        self.on_metric("mem.rss_mb", rss_bytes() / 1e6, time.perf_counter_ns())

    def _maybe_write_defs(self, now: int) -> None:
        regions = self.measurement.regions
        if (
            len(regions) == self._defs_regions
            and len(self._metric_ids) == self._defs_metrics
        ) or now - self._defs_t < DEFS_PERIOD_NS:
            return
        self._write_defs(now)

    def _write_defs(self, now: int) -> None:
        m = self.measurement
        cfg = m.config
        self._defs_regions = len(m.regions)
        with self._metric_lock:
            metrics = dict(self._metric_ids)
        self._defs_metrics = len(metrics)
        self._defs_t = now
        doc = {
            "meta": {
                "rank": cfg.topology.rank,
                "pid": os.getpid(),
                "experiment": cfg.experiment,
                "instrumenter": cfg.instrumenter,
                "topology": cfg.topology.as_dict(),
                "epoch_time_ns": m.epoch_time_ns,
                "epoch_perf_ns": m.epoch_perf_ns,
            },
            "regions": [
                [r["id"], f"{r['module']}:{r['name']}", r["kind"]]
                for r in m.regions.snapshot()
            ],
            "metrics": metrics,
            "streams": {str(v): k for k, v in self._streams.items()},
        }
        write_defs(defs_path_for(self.ring_path), doc)

    # -- health ----------------------------------------------------------------

    def describe(self) -> Dict[str, Any]:
        return {
            "ring": self.ring_path,
            "capacity": self.writer.capacity,
            "write_seq": self.writer.write_seq,
            "drops": self.writer.drops,
            "publish_ns": self.publish_ns,
            "stride": self.stride,
            "thinned_batches": self.thinned_batches,
            "thinned_records": self.thinned_records,
        }

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        self._write_defs(time.perf_counter_ns())
        self.writer.close()
