"""Live HTTP endpoint — the existing report model over a rolling window.

Stdlib-only (``http.server``): three read-only endpoints on a loopback
socket, backed by an :class:`~repro.agent.aggregator.Aggregator` that a
daemon thread drains continuously:

========================  ====================================================
``GET /report``           self-contained HTML (``core/report``'s renderer fed
                          the window snapshot instead of a finished run dir)
``GET /stats.json``       the schema-stamped window payload (same document
                          the HTML embeds; see docs/ARTIFACTS.md)
``GET /healthz``          ring lag, drop counts, heartbeat ages; ``status``
                          is ``ok`` / ``degraded`` (drops) / ``stale``
========================  ====================================================

The server never touches the measured process's state: everything it knows
arrived through the shared-memory ring, so the same class serves both the
in-process sidecar (``--agent``) and the external spectator
(``python -m repro.agent attach``).
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .aggregator import Aggregator

#: Aggregator drain period (seconds) — snappy enough for a live view,
#: far coarser than the writer's flush granularity.
POLL_S = 0.2


class AgentServer:
    """Aggregator drain loop + HTTP endpoint, both daemon threads."""

    def __init__(
        self,
        aggregator: Aggregator,
        host: str = "127.0.0.1",
        port: int = 0,
        poll_s: float = POLL_S,
    ):
        self.aggregator = aggregator
        self.poll_s = float(poll_s)
        self._stop = threading.Event()
        self._drainer: Optional[threading.Thread] = None
        self._server_thread: Optional[threading.Thread] = None

        agent = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *args):  # noqa: D102 - quiet by design
                pass

            def _send(self, body: bytes, content_type: str, code: int = 200):
                self.send_response(code)
                self.send_header("Content-Type", content_type)
                self.send_header("Content-Length", str(len(body)))
                self.send_header("Cache-Control", "no-store")
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path in ("/", "/report"):
                        from repro.core.report import render_report

                        page = render_report(agent.aggregator.snapshot())
                        self._send(page.encode("utf-8"), "text/html; charset=utf-8")
                    elif path == "/stats.json":
                        doc = agent.aggregator.snapshot()
                        self._send(
                            json.dumps(doc).encode("utf-8"), "application/json"
                        )
                    elif path == "/healthz":
                        doc = agent.aggregator.healthz()
                        code = 200 if doc["status"] == "ok" else 503
                        self._send(
                            json.dumps(doc).encode("utf-8"), "application/json", code
                        )
                    else:
                        self._send(b"not found\n", "text/plain", 404)
                except BrokenPipeError:  # client went away mid-write
                    pass
                except Exception as exc:  # never kill the serving thread
                    try:
                        self._send(
                            f"error: {exc!r}\n".encode(), "text/plain", 500
                        )
                    except OSError:
                        pass

        self.httpd = ThreadingHTTPServer((host, port), Handler)
        self.httpd.daemon_threads = True
        self.host, self.port = self.httpd.server_address[:2]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def _drain_loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self.aggregator.drain_once()
            except Exception:  # pragma: no cover - keep serving stale data
                pass

    def start(self) -> "AgentServer":
        self._stop.clear()
        self._drainer = threading.Thread(
            target=self._drain_loop, name="repro-agent-drain", daemon=True
        )
        self._drainer.start()
        self._server_thread = threading.Thread(
            target=self.httpd.serve_forever,
            kwargs={"poll_interval": 0.2},
            name="repro-agent-http",
            daemon=True,
        )
        self._server_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._drainer is not None:
            self._drainer.join(timeout=2.0)
            self._drainer = None
        if self._server_thread is not None:
            self._server_thread.join(timeout=2.0)
            self._server_thread = None
        # One last drain so post-stop snapshots (finalize paths, tests) see
        # everything that was published before shutdown.
        try:
            self.aggregator.drain_once()
        except Exception:
            pass
