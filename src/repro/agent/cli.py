"""Agent CLI — ``python -m repro.agent {attach,smoke}``.

``attach`` spectates a live measured process from outside: point it at an
``agent.ring`` file, a run dir containing one, or a root of per-rank run
dirs, and it tails the ring(s) and serves the same ``/report`` /
``/stats.json`` / ``/healthz`` endpoints the in-process sidecar serves.
Exit codes follow the ``analysis`` convention: 0 on success, 2 with a
one-line ``error:`` on a missing or corrupt ring.

``smoke`` is the CI live-path gate: it launches ``repro.launch.serve
--agent`` as a subprocess, polls ``/healthz`` until the endpoint is up,
fetches ``/report`` and ``/stats.json``, and asserts the end-to-end claims
(self-contained HTML, schema-stamped payload with populated window rows,
zero ring drops) before shutting the child down.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
import urllib.error
import urllib.request
from typing import List, Optional

from repro.core.schema import REPORT_SCHEMA_VERSION, MissingArtifact, SCHEMA_KEY

from .ringbus import RING_FILENAME, RingError

#: Needles whose presence would mean the live page pulls remote assets
#: (same self-containment gate as `analysis report --smoke`).
_CDN_NEEDLES = ("https://", "http://", "cdn.", "@import", 'src="//')


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.agent",
        description="Live monitoring agent: spectate a running measured "
        "process over its shared-memory ring, or run the CI live-path smoke.",
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    at = sub.add_parser(
        "attach",
        help="tail a live process's ring(s) and serve /report over the window",
    )
    at.add_argument(
        "ring",
        help="agent.ring path, a run dir containing one, or a root dir of "
        "per-rank run dirs",
    )
    at.add_argument("--port", type=int, default=0,
                    help="HTTP port (0 = ephemeral)")
    at.add_argument("--window", type=float, default=60.0,
                    help="rolling window length in seconds")
    at.add_argument("--once", action="store_true",
                    help="drain once, print the window payload JSON to "
                         "stdout, and exit (no HTTP server)")
    at.add_argument("--duration", type=float, default=0.0,
                    help="serve for N seconds then exit (0 = until Ctrl-C)")

    sm = sub.add_parser(
        "smoke",
        help="end-to-end live-path smoke: launch serve --agent, poll "
        "/healthz, assert /report + /stats.json + zero drops",
    )
    sm.add_argument("--arch", default="mamba2-370m",
                    help="model arch for the serving workload")
    sm.add_argument("--port", type=int, default=8707)
    sm.add_argument("--timeout", type=float, default=240.0,
                    help="overall smoke deadline in seconds")
    sm.add_argument("--out", default="",
                    help="write the smoke result JSON here")
    return p


# -- attach -------------------------------------------------------------------


def find_rings(path: str) -> List[str]:
    """Resolve a ring file / run dir / root dir argument to ring paths."""
    if os.path.isfile(path):
        return [path]
    if os.path.isdir(path):
        direct = os.path.join(path, RING_FILENAME)
        if os.path.exists(direct):
            return [direct]
        rings = []
        for entry in sorted(os.scandir(path), key=lambda e: e.name):
            if entry.is_dir():
                ring = os.path.join(entry.path, RING_FILENAME)
                if os.path.exists(ring):
                    rings.append(ring)
        return rings
    return []


def cmd_attach(ns: argparse.Namespace) -> int:
    from .aggregator import Aggregator
    from .serve import AgentServer

    rings = find_rings(ns.ring)
    if not rings:
        raise MissingArtifact(
            f"no {RING_FILENAME} at {ns.ring} — launch the target with an "
            "agent (repro.scorep --agent, launch serve --agent, or "
            "REPRO_MONITOR_AGENT=1)"
        )
    try:
        aggregator = Aggregator(paths=tuple(rings), window_s=ns.window)
    except RingError as exc:
        raise MissingArtifact(str(exc)) from exc
    if ns.once:
        aggregator.drain_once()
        print(json.dumps(aggregator.snapshot(), indent=1))
        aggregator.close()
        return 0
    server = AgentServer(aggregator, port=ns.port).start()
    print(
        f"agent: spectating {len(rings)} ring(s) at {server.url} "
        f"(/report /stats.json /healthz); Ctrl-C to stop"
    )
    try:
        if ns.duration > 0:
            time.sleep(ns.duration)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
        aggregator.close()
    return 0


# -- smoke --------------------------------------------------------------------


def _http_json(url: str, timeout: float = 5.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return json.loads(resp.read().decode("utf-8"))


def cmd_smoke(ns: argparse.Namespace) -> int:
    base = f"http://127.0.0.1:{ns.port}"
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", ns.arch, "--smoke",
        "--batch", "2", "--prompt-len", "16", "--gen", "8",
        "--loop", "10000",
        "--agent", "--agent-port", str(ns.port),
    ]
    print(f"smoke: launching {' '.join(cmd)}")
    proc = subprocess.Popen(cmd)
    deadline = time.monotonic() + ns.timeout
    result = {"arch": ns.arch, "port": ns.port}
    try:
        # 1. Poll /healthz until the endpoint answers.
        health = None
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                print(f"error: serve child exited early ({proc.returncode})",
                      file=sys.stderr)
                return 1
            try:
                health = _http_json(base + "/healthz")
                break
            except (urllib.error.URLError, OSError, ValueError):
                time.sleep(0.5)
        if health is None:
            print("error: /healthz never became reachable", file=sys.stderr)
            return 1
        print(f"smoke: /healthz up (status={health['status']})")

        # 2. Poll /stats.json until the window has populated region rows.
        # Individual requests may stall while the child's first JAX compile
        # holds the GIL — treat those like "not up yet" and keep polling.
        stats = None
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                print(f"error: serve child exited early ({proc.returncode})",
                      file=sys.stderr)
                return 1
            try:
                stats = _http_json(base + "/stats.json", timeout=10.0)
            except (urllib.error.URLError, OSError, ValueError):
                time.sleep(1.0)
                continue
            if any(r.get("visits", 0) > 0 for r in stats.get("regions", [])):
                break
            time.sleep(1.0)
        assert stats is not None and stats.get(SCHEMA_KEY) == REPORT_SCHEMA_VERSION, (
            f"stats.json missing schema stamp: {None if stats is None else stats.get(SCHEMA_KEY)}"
        )
        rows = [r for r in stats["regions"] if r.get("visits", 0) > 0]
        assert rows, "window never populated with region rows"
        assert stats.get("window", {}).get("rings"), "window payload lists no rings"
        result["regions"] = len(rows)
        result["events"] = stats["window"]["events"]
        print(f"smoke: /stats.json OK ({len(rows)} live regions, "
              f"{stats['window']['events']} events in window)")

        # 3. /report: self-contained HTML embedding the same payload.
        with urllib.request.urlopen(base + "/report", timeout=30.0) as resp:
            page = resp.read().decode("utf-8")
        from repro.core.report import extract_payload

        payload = extract_payload(page)
        assert payload.get(SCHEMA_KEY) == REPORT_SCHEMA_VERSION
        assert payload.get("meta", {}).get("live") is True
        for needle in _CDN_NEEDLES:
            assert needle not in page.replace("http://127.0.0.1", ""), (
                f"live report is not self-contained: found {needle!r}"
            )
        print(f"smoke: /report OK ({len(page)} bytes, self-contained)")

        # 4. Zero ring drops across the whole exercise.
        health = _http_json(base + "/healthz", timeout=30.0)
        assert health["drops"] == 0, f"ring drops in smoke: {health['drops']}"
        result["drops"] = health["drops"]
        result["status"] = health["status"]
        print("smoke: zero ring drops")
        return 0
    except AssertionError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    finally:
        # Clean shutdown: SIGINT lets the child's atexit finalize run.
        if proc.poll() is None:
            proc.send_signal(signal.SIGINT)
            try:
                proc.wait(timeout=30.0)
            except subprocess.TimeoutExpired:
                proc.terminate()
                try:
                    proc.wait(timeout=10.0)
                except subprocess.TimeoutExpired:
                    proc.kill()
        print(f"smoke: serve child exited ({proc.returncode})")
        if ns.out:
            result["returncode"] = proc.returncode
            os.makedirs(os.path.dirname(ns.out) or ".", exist_ok=True)
            with open(ns.out, "w") as fh:
                json.dump(result, fh, indent=1)
            print(f"smoke: wrote {ns.out}")


def main(argv: Optional[List[str]] = None) -> int:
    ns = build_parser().parse_args(argv)
    try:
        if ns.cmd == "attach":
            return cmd_attach(ns)
        if ns.cmd == "smoke":
            return cmd_smoke(ns)
    except MissingArtifact as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
