"""Live continuous-monitoring agent.

A measured process with ``MeasurementConfig.agent`` set publishes its flush
batches and metric samples into a lock-free shared-memory ring
(:mod:`repro.agent.ringbus`) at a cost the governor accounts against the
overhead budget.  A sidecar (in-process on rank 0, or an external
``python -m repro.agent attach``) tails the ring(s), maintains rolling
-window per-region statistics (:mod:`repro.agent.aggregator`), and serves
``/report`` (live HTML), ``/stats.json`` (schema-stamped window payload)
and ``/healthz`` (ring lag / drops) over loopback HTTP
(:mod:`repro.agent.serve`).

See ARCHITECTURE.md ("Live monitoring agent") for the ring layout, window
semantics and the degradation ladder.
"""

from .aggregator import Aggregator, RingTail
from .publisher import AgentPublisher
from .ringbus import (
    DEFS_FILENAME,
    RING_FILENAME,
    RingError,
    RingReader,
    RingWriter,
    decode_records,
    encode_columns,
    encode_metric,
)
from .runtime import AgentRuntime
from .serve import AgentServer

__all__ = [
    "Aggregator",
    "AgentPublisher",
    "AgentRuntime",
    "AgentServer",
    "DEFS_FILENAME",
    "RING_FILENAME",
    "RingError",
    "RingReader",
    "RingTail",
    "RingWriter",
    "decode_records",
    "encode_columns",
    "encode_metric",
]
