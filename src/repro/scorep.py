"""``python -m repro.scorep`` — the paper's CLI, verbatim in spirit.

    mpirun -n 2 python -m scorep --mpp=mpi --thread=pthread ./run.py  (paper)
    python -m repro.scorep --mpp=jax ./run.py                          (here)
"""

from repro.core.bootstrap import main

if __name__ == "__main__":
    raise SystemExit(main())
