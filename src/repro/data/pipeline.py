"""Background prefetch + host-shard slicing for the data sources."""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


def host_shard(batch: Dict[str, np.ndarray], host_index: int, n_hosts: int) -> Dict[str, np.ndarray]:
    """Slice a global batch to this host's rows (multi-host data loading:
    each host materializes only its shard)."""
    out = {}
    for key, arr in batch.items():
        n = arr.shape[0]
        assert n % n_hosts == 0, (key, n, n_hosts)
        per = n // n_hosts
        out[key] = arr[host_index * per : (host_index + 1) * per]
    return out


class Prefetcher:
    """Runs ``source.batch(step)`` in a worker thread, ``depth`` ahead."""

    def __init__(self, batch_fn: Callable[[int], Dict], start_step: int = 0, depth: int = 2):
        self._fn = batch_fn
        self._queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._queue.put((step, self._fn(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self):
        step, batch = self._queue.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
