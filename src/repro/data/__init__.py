"""Data pipeline: stateless seeded sources + prefetch."""

from .pipeline import Prefetcher, host_shard  # noqa: F401
from .synthetic import DataConfig, MemmapCorpus, SyntheticLM  # noqa: F401
