"""Stateless, seeded data pipeline.

Batches are a pure function of (seed, step): restarts, elastic re-meshes and
multi-host resumption reproduce the exact token stream with no iterator
state to checkpoint — the data-side half of fault tolerance.  Two sources:

  * SyntheticLM: deterministic pseudo-corpus (hash-mixed token ids with a
    skewed unigram distribution, document boundaries, next-token labels).
  * MemmapCorpus: flat token file on disk (np.memmap), sliced by a
    (seed, step)-keyed permutation — the production path.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bos_id: int = 1
    # modality stubs
    frontend_tokens: int = 0
    frontend_dim: int = 0
    encoder_len: int = 0
    encoder_dim: int = 0


def _mix(a: np.ndarray, b: int) -> np.ndarray:
    """64-bit splitmix-style hash, vectorized (wraparound intended)."""
    with np.errstate(over="ignore"):
        x = a.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15) * np.uint64((b + 1) & 0xFFFFFFFF)
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return x


class SyntheticLM:
    """Deterministic synthetic LM batches keyed by (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        n = c.global_batch * (c.seq_len + 1)
        idx = np.arange(n, dtype=np.uint64) + np.uint64(step) * np.uint64(n)
        h = _mix(idx, c.seed)
        # skewed unigram: square a uniform to concentrate mass at low ids
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = (u * u * (c.vocab - 2)).astype(np.int32) + 2
        # sprinkle document boundaries (bos) every ~512 tokens
        bos_mask = (_mix(idx, c.seed + 7) % np.uint64(512)) == 0
        toks = np.where(bos_mask, c.bos_id, toks)
        toks = toks.reshape(c.global_batch, c.seq_len + 1)
        out = {"tokens": toks[:, :-1].astype(np.int32), "labels": toks[:, 1:].astype(np.int32)}
        if c.frontend_tokens:
            m = _mix(np.arange(c.global_batch * c.frontend_tokens * c.frontend_dim, dtype=np.uint64), c.seed + step)
            out["patches"] = (
                (m.astype(np.float64) / float(2**64) - 0.5).reshape(
                    c.global_batch, c.frontend_tokens, c.frontend_dim
                )
            ).astype(np.float32)
        if c.encoder_len:
            m = _mix(np.arange(c.global_batch * c.encoder_len * c.encoder_dim, dtype=np.uint64), c.seed + 13 + step)
            out["frames"] = (
                (m.astype(np.float64) / float(2**64) - 0.5).reshape(
                    c.global_batch, c.encoder_len, c.encoder_dim
                )
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MemmapCorpus:
    """Flat-token-file corpus with (seed, step)-keyed window selection."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len
        if self.n_windows < cfg.global_batch:
            raise ValueError("corpus too small for one batch")

    @staticmethod
    def write(path: str, tokens: np.ndarray) -> None:
        mm = np.memmap(path, dtype=np.int32, mode="w+", shape=tokens.shape)
        mm[:] = tokens
        mm.flush()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        idx = np.arange(c.global_batch, dtype=np.uint64) + np.uint64(step) * np.uint64(c.global_batch)
        win = (_mix(idx, c.seed) % np.uint64(self.n_windows)).astype(np.int64)
        starts = win * c.seq_len
        toks = np.stack([self.tokens[s : s + c.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1].astype(np.int32), "labels": toks[:, 1:].astype(np.int32)}
