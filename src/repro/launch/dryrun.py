import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first backend initialization (the same reason the paper's bootstrap
re-execs the interpreter for LD_PRELOAD).

Per cell this script:
  1. builds the AOT-jitted step (train_step / prefill_step / serve_step),
  2. ``.lower()`` with ShapeDtypeStruct inputs (no allocation),
  3. ``.compile()`` — sharding mismatches / unsupported collectives fail here,
  4. records ``memory_analysis()`` (fits-per-device proof), ``cost_analysis()``
     (FLOPs/bytes) and per-collective wire bytes into a JSON artifact that
     the roofline harness (benchmarks/roofline.py) consumes.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

import repro.core as rmon
from repro.configs import SHAPE_CELLS, all_cells, cell_applicable, get_config, get_shape_cell
from repro.core.jax_events import collective_stats, compiled_metrics
from repro.dist import serve as dserve
from repro.dist import train as dtrain
from repro.launch.mesh import make_production_mesh

DEFAULT_OUT = os.path.join("benchmarks", "artifacts", "dryrun")


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    cell = get_shape_cell(shape)
    if cell.kind == "train":
        return dtrain.batch_shapes(cfg, cell.global_batch, cell.seq_len)
    if cell.kind == "prefill":
        return dserve.prefill_batch_shapes(cfg, cell.global_batch, cell.seq_len)
    # decode: one new token against a cache of seq_len
    import jax.numpy as jnp

    return {"token": jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)}


def lower_cell(arch: str, shape: str, mesh) -> Any:
    """Build + lower one cell; returns the lowered computation."""
    cfg = get_config(arch)
    cell = get_shape_cell(shape)
    with mesh:
        if cell.kind == "train":
            compile_for = dtrain.jit_train_step(cfg, mesh)
            batch_abstract = dtrain.batch_shapes(cfg, cell.global_batch, cell.seq_len)
            jitted, (params_s, opt_s, batch_s) = compile_for(batch_abstract)
            return jitted.lower(params_s, opt_s, batch_s)
        if cell.kind == "prefill":
            jitted, (params_s, batch_s) = dserve.jit_prefill_step(
                cfg, mesh, cell.global_batch, cell.seq_len
            )
            return jitted.lower(params_s, batch_s)
        # decode
        jitted, (params_s, cache_s, tok_s) = dserve.jit_serve_step(
            cfg, mesh, cell.global_batch, cell.seq_len
        )
        return jitted.lower(params_s, cache_s, tok_s)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> Dict[str, Any]:
    cfg = get_config(arch)
    cell = get_shape_cell(shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "kind": cell.kind,
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
    }
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        record["status"] = "skip"
        record["reason"] = reason
        return _save(record, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    record["topology"] = rmon.current_topology().with_mesh(mesh).as_dict()
    t0 = time.time()
    try:
        with rmon.region(f"lower:{arch}:{shape}:{mesh_name}", module="dryrun"):
            lowered = lower_cell(arch, shape, mesh)
        t1 = time.time()
        with rmon.region(f"compile:{arch}:{shape}:{mesh_name}", module="dryrun"):
            with mesh:
                compiled = lowered.compile()
        t2 = time.time()
    except Exception as exc:  # noqa: BLE001 - recorded as cell failure
        record["status"] = "fail"
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc()[-4000:]
        return _save(record, out_dir)

    mem = compiled.memory_analysis()
    metrics = compiled_metrics(compiled)
    record.update(
        {
            "status": "ok",
            "devices": int(n_dev),
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory_analysis": {
                k: int(getattr(mem, k, 0) or 0)
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "alias_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
            },
            "cost_analysis": {
                "flops": metrics["hlo_flops"],
                "bytes_accessed": metrics["hlo_bytes"],
            },
            "collectives": collective_stats(compiled.as_text()),
            "collective_wire_bytes": metrics["collective_wire_bytes"],
        }
    )
    # proof prints required by the dry-run contract
    print(f"[{arch} x {shape} x {mesh_name}] memory_analysis:", mem)
    print(
        f"[{arch} x {shape} x {mesh_name}] cost_analysis: flops={metrics['hlo_flops']:.3e} "
        f"bytes={metrics['hlo_bytes']:.3e} collective_wire_bytes={metrics['collective_wire_bytes']:.3e}"
    )
    return _save(record, out_dir)


def _save(record: Dict[str, Any], out_dir: str) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as fh:
        json.dump(record, fh, indent=1)
    status = record["status"]
    extra = record.get("reason") or record.get("error", "")
    print(f"{status.upper():5s} {record['arch']:20s} {record['shape']:12s} {record['mesh']}  {extra}")
    return record


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.launch.dryrun")
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=[c.name for c in SHAPE_CELLS] + [None])
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--all", action="store_true", help="run every (arch x shape) cell")
    p.add_argument("--out", default=DEFAULT_OUT)
    ns = p.parse_args(argv)

    assert len(jax.devices()) == 512, (
        f"dry-run needs 512 placeholder devices, got {len(jax.devices())}; "
        "XLA_FLAGS was set too late"
    )

    cells = (
        all_cells()
        if ns.all
        else [(ns.arch, ns.shape)]
        if ns.arch and ns.shape
        else [(ns.arch, c.name) for c in SHAPE_CELLS]
        if ns.arch
        else all_cells()
    )
    meshes = [False, True] if ns.both_meshes else [ns.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, ns.out)
            failures += rec["status"] == "fail"
    print(f"dry-run complete: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
