"""Mesh construction.  Functions, not module constants — importing this
module never touches jax device state (required by the dry-run contract)."""

from __future__ import annotations

from typing import Optional, Tuple

from repro import _compat  # noqa: F401  (jax API shims: axis_types, shard_map)

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one pod = 16x16 = 256 chips (v5e), two pods for
    the multi-pod dry-run.  'pod' composes with 'data' for gradient
    reduction (pure DP across pods: inter-pod links are the slowest, so only
    per-step gradient all-reduce crosses them)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_elastic_mesh(n_devices: Optional[int] = None, model_parallel: int = 16):
    """Elastic-scaling helper: build the largest (data, model) mesh available.

    Used on restart after losing hosts: model_parallel stays fixed (weights
    reshard cleanly), the data axis absorbs whatever is left."""
    devices = jax.devices()
    n = n_devices or len(devices)
    model = min(model_parallel, n)
    while n % model:
        model //= 2
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
        devices=devices[: data * model],
    )


def make_pipeline_mesh(n_stages: int, n_data: int):
    """Mesh with an explicit 'stage' axis for GPipe pipeline parallelism."""
    return jax.make_mesh(
        (n_data, n_stages), ("data", "stage"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.shape.values())} axes={mesh.axis_names} devices={mesh.devices.size}"


def elastic_setup(cfg, topology, use_mesh: bool):
    """Common driver bootstrap: resolve the elastic mesh (when requested and
    >1 device is visible), install activation sharding on the config, and
    bind the mesh shape into the topology.

    Returns ``(cfg, mesh, mesh_ctx, topology)`` where ``mesh`` is None on
    the single-device path and ``mesh_ctx()`` yields the context the jitted
    step must be *called* under — activation PartitionSpec constraints
    resolve against the ambient mesh at trace time, not jit-creation time.
    """
    import contextlib

    from repro.dist.train import with_act_sharding

    if use_mesh and len(jax.devices()) > 1:
        mesh = make_elastic_mesh()
        return with_act_sharding(cfg, mesh), mesh, (lambda: mesh), topology.with_mesh(mesh)
    return cfg, None, contextlib.nullcontext, topology
