"""Mesh construction.  Functions, not module constants — importing this
module never touches jax device state (required by the dry-run contract)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one pod = 16x16 = 256 chips (v5e), two pods for
    the multi-pod dry-run.  'pod' composes with 'data' for gradient
    reduction (pure DP across pods: inter-pod links are the slowest, so only
    per-step gradient all-reduce crosses them)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_elastic_mesh(n_devices: Optional[int] = None, model_parallel: int = 16):
    """Elastic-scaling helper: build the largest (data, model) mesh available.

    Used on restart after losing hosts: model_parallel stays fixed (weights
    reshard cleanly), the data axis absorbs whatever is left."""
    devices = jax.devices()
    n = n_devices or len(devices)
    model = min(model_parallel, n)
    while n % model:
        model //= 2
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
        devices=devices[: data * model],
    )


def make_pipeline_mesh(n_stages: int, n_data: int):
    """Mesh with an explicit 'stage' axis for GPipe pipeline parallelism."""
    return jax.make_mesh(
        (n_data, n_stages), ("data", "stage"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def describe(mesh) -> str:
    return f"mesh{tuple(mesh.shape.values())} axes={mesh.axis_names} devices={mesh.devices.size}"
