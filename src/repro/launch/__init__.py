"""Launchers: mesh construction, dry-run, train/serve drivers, trace CLI."""
