"""Serving driver: batched prefill + greedy decode with monitoring.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as rmon
from repro.core.memsys import rss_bytes
from repro.configs import get_config, get_smoke_config
from repro.dist import serve as dserve
from repro.models import lm_init


def serve(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 32,
    seed: int = 0,
    use_mesh: bool = False,
) -> Dict[str, Any]:
    from repro.launch.mesh import elastic_setup

    cfg, mesh, mesh_ctx, topology = elastic_setup(cfg, rmon.current_topology(), use_mesh)

    key = jax.random.PRNGKey(seed)
    with rmon.region("init", module="serve"):
        params = lm_init(key, cfg)
        if mesh is not None:
            from repro.dist import sharding as shd

            params = jax.device_put(params, shd.params_shardings(mesh, params))
    max_len = prompt_len + gen + (cfg.frontend.n_tokens if cfg.frontend else 0)
    prompts = jax.random.randint(key, (batch, prompt_len), 2, cfg.vocab)
    host_batch = {"tokens": prompts}
    if cfg.frontend is not None:
        host_batch["patches"] = jax.random.normal(
            key, (batch, cfg.frontend.n_tokens, cfg.frontend.dim), jnp.bfloat16)
    if cfg.encoder is not None:
        host_batch["frames"] = jax.random.normal(
            key, (batch, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16)

    prefill_fn = jax.jit(dserve.make_prefill_step(cfg, max_len))
    decode_fn = jax.jit(dserve.make_decode_step(cfg))

    t0 = time.perf_counter()
    with rmon.region("prefill", module="serve"), mesh_ctx():
        logits, cache = jax.block_until_ready(prefill_fn(params, host_batch))
    t_prefill = time.perf_counter() - t0
    rmon.metric("serve.prefill_ms", t_prefill * 1e3)
    # Slot memory watermark after prefill: the KV cache for all slots is
    # materialized here, so this is the high-water mark per batch of slots.
    rmon.metric("serve.prefill_rss_mb", rss_bytes() / 1e6)

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [tok]
    t1 = time.perf_counter()
    for i in range(gen - 1):
        with rmon.region("decode_step", module="serve"), mesh_ctx():
            logits, cache = decode_fn(params, cache, tok)
            logits = jax.block_until_ready(logits)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
    t_decode = time.perf_counter() - t1
    rmon.metric("serve.decode_tok_s", batch * (gen - 1) / max(t_decode, 1e-9))
    rmon.metric("serve.decode_rss_mb", rss_bytes() / 1e6)

    out = jnp.concatenate(generated, axis=1)
    return {
        "batch": batch,
        "prompt_len": prompt_len,
        "generated": int(out.shape[1]),
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
        "finite": bool(np.all(np.isfinite(np.asarray(logits)))),
        "sample_tokens": np.asarray(out[0, :8]).tolist(),
        "topology": topology.as_dict(),
    }


def build_parser() -> argparse.ArgumentParser:
    """The ``python -m repro.launch.serve`` argument parser (also rendered
    into docs/CLI.md by :mod:`repro.core.clidoc`)."""
    p = argparse.ArgumentParser(prog="python -m repro.launch.serve")
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--mesh", action="store_true")
    p.add_argument("--report", action="store_true",
                   help="emit report.html at finalize: flips the active "
                        "measurement's report flag when launched under "
                        "repro.scorep, else starts a measurement of its own")
    p.add_argument("--static-plan", dest="static_plan", default="",
                   help="static_plan.json from `analysis plan`: applied to "
                        "the active measurement (or the one --report starts)")
    p.add_argument("--agent", action="store_true",
                   help="run the live-monitoring agent alongside the workload "
                        "(/report, /stats.json, /healthz); attaches to the "
                        "active measurement when launched under repro.scorep, "
                        "else starts a measurement of its own")
    p.add_argument("--agent-port", type=int, default=0,
                   help="agent HTTP port (0 = ephemeral)")
    p.add_argument("--loop", type=int, default=1,
                   help="repeat the serve workload N times (live-monitoring "
                        "demos/smokes: keeps events flowing; Ctrl-C exits "
                        "cleanly after the current iteration)")
    return p


def main(argv=None) -> int:
    ns = build_parser().parse_args(argv)
    owns_measurement = False
    if ns.report or ns.agent:
        m = rmon.active()
        if m is None:
            rmon.init(experiment="serve", report=ns.report,
                      agent=ns.agent, agent_port=ns.agent_port,
                      static_plan=ns.static_plan,
                      substrates=("profiling", "tracing", "metrics", "memory"))
            owns_measurement = True
        else:
            if ns.report:
                m.config.report = True
            if ns.agent:
                m.attach_agent(ns.agent_port)
    if ns.static_plan and not owns_measurement:
        m = rmon.active()
        if m is not None:
            from repro.core.staticpass import apply_plan, load_plan

            apply_plan(m, load_plan(ns.static_plan))
    cfg = get_smoke_config(ns.arch) if ns.smoke else get_config(ns.arch)
    result = None
    try:
        for i in range(max(1, ns.loop)):
            result = serve(cfg, batch=ns.batch, prompt_len=ns.prompt_len,
                           gen=ns.gen, use_mesh=ns.mesh)
            if ns.loop > 1:
                rmon.metric("serve.iteration", i + 1)
    except KeyboardInterrupt:
        pass  # clean exit mid-loop: fall through to finalize below
    if result is not None:
        print(result)
    if owns_measurement:
        run_dir = rmon.finalize()
        if run_dir and ns.report:
            print(f"report: {run_dir}/report.html")
    return 0 if (result is None or result["finite"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
