"""Alias launcher: ``python -m repro.launch.trace`` == ``python -m repro.scorep``."""

from repro.core.bootstrap import main

if __name__ == "__main__":
    raise SystemExit(main())
