"""End-to-end training driver.

Ties together: config registry, elastic mesh, stateless data pipeline,
AdamW, monitoring (paper's regions + metrics), straggler watchdog, and
fault-tolerant checkpointing with auto-resume.

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \
        --steps 50 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt

Runs under the monitoring CLI exactly like any Python program (paper
Listing 1):

    python -m repro.scorep --instrumenter=profile -- \
        -m is not needed; pass the script path or use mod: syntax
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as rmon
from repro.core.memsys import rss_bytes
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.dist import sharding as shd
from repro.dist.straggler import StragglerWatchdog
from repro.dist.train import make_train_step
from repro.models import lm_init
from repro.models.lm import padded_vocab
from repro.optim import adamw


def build_data_config(cfg, global_batch: int, seq_len: int, seed: int) -> DataConfig:
    return DataConfig(
        vocab=cfg.vocab,
        seq_len=seq_len if cfg.frontend is None else seq_len - cfg.frontend.n_tokens,
        global_batch=global_batch,
        seed=seed,
        frontend_tokens=cfg.frontend.n_tokens if cfg.frontend else 0,
        frontend_dim=cfg.frontend.dim if cfg.frontend else 0,
        encoder_len=cfg.encoder.source_len if cfg.encoder else 0,
        encoder_dim=cfg.d_model if cfg.encoder else 0,
    )


def train(
    cfg,
    *,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 128,
    lr: float = 3e-4,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    use_mesh: bool = False,
    log_every: int = 10,
    abort_at_step: Optional[int] = None,  # simulate a crash (no final save)
) -> Dict[str, Any]:
    opt_cfg = adamw.AdamWConfig(lr=lr, schedule=adamw.cosine_schedule(max(steps // 10, 1), steps))
    from repro.launch.mesh import elastic_setup

    cfg, mesh, mesh_ctx, topology = elastic_setup(cfg, rmon.current_topology(), use_mesh)
    if topology.world_size > 1 or topology.mesh_shape:
        print(f"topology: {topology.tag()} mesh={topology.mesh_shape or '(none)'}")

    with rmon.region("init", module="train"):
        params = lm_init(jax.random.PRNGKey(seed), cfg)
        opt_state = adamw.init(params)
        if mesh is not None:
            p_shard = shd.params_shardings(mesh, params)
            o_shard = shd.opt_state_shardings(mesh, opt_state)
            params = jax.device_put(params, p_shard)
            opt_state = jax.device_put(opt_state, o_shard)

    start_step = 0
    manager = None
    if ckpt_dir:
        manager = CheckpointManager(ckpt_dir)
        state = {"params": params, "opt": opt_state}
        shardings = None
        if mesh is not None:
            shardings = {"params": p_shard, "opt": o_shard}
        restored = manager.restore_latest(state, shardings)
        if restored is not None:
            start_step, state, extras = restored
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from checkpoint at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    data = SyntheticLM(build_data_config(cfg, global_batch, seq_len, seed))
    prefetch = Prefetcher(data.batch, start_step=start_step)
    watchdog = StragglerWatchdog(
        topology=topology,
        on_straggler=lambda ev: print(
            f"straggler: step {ev['step']} {ev['ratio']:.1f}x baseline on rank {ev['rank']}"
        ),
    )

    losses = []
    t_train0 = time.perf_counter()
    try:
        for i in range(start_step, steps):
            step_i, host_batch = prefetch.next()
            batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            if "patches" in batch:
                batch["patches"] = batch["patches"].astype(jnp.bfloat16)
            if "frames" in batch:
                batch["frames"] = batch["frames"].astype(jnp.bfloat16)
            t0 = time.perf_counter()
            with rmon.region("train_step", module="train"), mesh_ctx():
                params, opt_state, stats = step_fn(params, opt_state, batch)
                stats = jax.block_until_ready(stats)
            dt = time.perf_counter() - t0
            watchdog.observe(step_i, dt)
            loss = float(stats["loss"])
            losses.append(loss)
            rmon.metric("train.loss", loss)
            rmon.metric("train.tokens", global_batch * seq_len)
            # Per-step memory watermark: host RSS after the step completed
            # (device buffers live in RSS on CPU backends; on accelerators
            # this tracks the host-side share — staging, prefetch, optimizer
            # mirrors).  Feeds the mem counter tracks in the trace view.
            rmon.metric("train.rss_mb", rss_bytes() / 1e6)
            if (step_i + 1) % log_every == 0 or step_i == start_step:
                tps = global_batch * seq_len / dt
                print(
                    f"step {step_i + 1:5d}  loss {loss:.4f}  grad_norm "
                    f"{float(stats['grad_norm']):.3f}  {dt * 1e3:.0f} ms  {tps:,.0f} tok/s"
                )
            if manager and (step_i + 1) % ckpt_every == 0:
                with rmon.region("checkpoint", module="train"):
                    manager.save(step_i + 1, {"params": params, "opt": opt_state},
                                 extras={"loss": loss})
            if abort_at_step is not None and step_i + 1 >= abort_at_step:
                # simulated crash: leave without final save; whatever the
                # checkpoint cadence published is what restart sees
                if manager:
                    manager.wait()
                return {
                    "steps": step_i + 1 - start_step,
                    "start_step": start_step,
                    "final_loss": losses[-1],
                    "first_loss": losses[0],
                    "wall_s": time.perf_counter() - t_train0,
                    "aborted": True,
                    "straggler": watchdog.summary(),
                }
        if manager:
            manager.save(steps, {"params": params, "opt": opt_state},
                         extras={"loss": losses[-1] if losses else None})
            manager.wait()
    finally:
        prefetch.close()

    wall = time.perf_counter() - t_train0
    result = {
        "steps": steps - start_step,
        "start_step": start_step,
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "wall_s": wall,
        "straggler": watchdog.summary(),
        "topology": topology.as_dict(),
    }
    return result


def build_parser() -> argparse.ArgumentParser:
    """The ``python -m repro.launch.train`` argument parser (also rendered
    into docs/CLI.md by :mod:`repro.core.clidoc`)."""
    p = argparse.ArgumentParser(prog="python -m repro.launch.train")
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true", help="use the reduced config")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--mesh", action="store_true")
    p.add_argument("--d-model", type=int, default=None, help="override width")
    p.add_argument("--n-groups", type=int, default=None, help="override depth")
    p.add_argument("--report", action="store_true",
                   help="emit report.html at finalize: flips the active "
                        "measurement's report flag when launched under "
                        "repro.scorep, else starts a measurement of its own")
    p.add_argument("--static-plan", dest="static_plan", default="",
                   help="static_plan.json from `analysis plan`: applied to "
                        "the active measurement (or the one --report starts)")
    return p


def main(argv=None) -> int:
    ns = build_parser().parse_args(argv)

    owns_measurement = False
    if ns.report:
        m = rmon.active()
        if m is not None:
            m.config.report = True
        else:
            rmon.init(experiment="train", report=True,
                      static_plan=ns.static_plan,
                      substrates=("profiling", "tracing", "metrics", "memory"))
            owns_measurement = True
    if ns.static_plan and not owns_measurement:
        m = rmon.active()
        if m is not None:
            from repro.core.staticpass import apply_plan, load_plan

            apply_plan(m, load_plan(ns.static_plan))

    cfg = get_smoke_config(ns.arch) if ns.smoke else get_config(ns.arch)
    overrides = {}
    if ns.d_model:
        overrides["d_model"] = ns.d_model
    if ns.n_groups:
        overrides["n_groups"] = ns.n_groups
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    result = train(
        cfg,
        steps=ns.steps,
        global_batch=ns.global_batch,
        seq_len=ns.seq_len,
        lr=ns.lr,
        seed=ns.seed,
        ckpt_dir=ns.ckpt_dir,
        ckpt_every=ns.ckpt_every,
        use_mesh=ns.mesh,
    )
    print(result)
    if owns_measurement:
        run_dir = rmon.finalize()
        if run_dir:
            print(f"report: {run_dir}/report.html")
    ok = result["final_loss"] is not None and np.isfinite(result["final_loss"])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
