"""Assigned architecture configs + shape cells."""

from .base import (  # noqa: F401
    EncoderConfig,
    FrontendConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RNNConfig,
    SHAPE_CELLS,
    SSMConfig,
    ShapeCell,
    get_shape_cell,
)
from .registry import (  # noqa: F401
    ARCHS,
    all_cells,
    cell_applicable,
    get_config,
    get_smoke_config,
)
