"""mistral-nemo-12b — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    pattern=(("attn", "mlp"),),
    n_groups=40,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mistral-nemo-12b-smoke",
    family="dense",
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "mlp"),),
    n_groups=2,
    rope_theta=1_000_000.0,
    remat="none",
)
