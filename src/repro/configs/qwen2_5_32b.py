"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

64L d_model=5120 40H (GQA kv=8) head_dim=128 d_ff=27648 vocab=152064.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    pattern=(("attn", "mlp"),),
    n_groups=64,
    rope_theta=1_000_000.0,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke",
    family="dense",
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "mlp"),),
    n_groups=2,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    remat="none",
)
