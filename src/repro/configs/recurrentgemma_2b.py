"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1) head_dim=256 d_ff=7680 vocab=256000,
lru_width=2560, window=2048.  Layout: (recurrent, recurrent, attention)
repeated; 26 = 8 x (R,R,A) + (R,R).
"""

from .base import ModelConfig, RNNConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("attn_local", "mlp")),
    n_groups=8,
    tail_pattern=(("rglru", "mlp"), ("rglru", "mlp")),
    window=2048,
    rope_theta=10_000.0,
    rnn=RNNConfig(d_rnn=2560, conv_width=4),
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",
    sub_quadratic=True,  # O(1) recurrent state + bounded window
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
    pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("attn_local", "mlp")),
    n_groups=2,
    tail_pattern=(("rglru", "mlp"), ("rglru", "mlp")),
    window=8,
    rnn=RNNConfig(d_rnn=128, conv_width=4),
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",
    sub_quadratic=True,
    remat="none",
)
