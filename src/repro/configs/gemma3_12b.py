"""gemma3-12b — dense, 5:1 local:global interleave, 128k ctx
[hf:google/gemma-3-1b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) head_dim=256 d_ff=15360 vocab=262144.
Local layers: window 1024, rope theta 10k; global layers: rope theta 1M.
QK-norm, tied embeddings, embeddings scaled by sqrt(d).
"""

from .base import ModelConfig

_PATTERN = (("attn_local", "mlp"),) * 5 + (("attn", "mlp"),)

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=_PATTERN,
    n_groups=8,
    window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",
    sub_quadratic=True,  # 5/6 of layers are window-1024; each 6th keeps full KV
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke",
    family="dense",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    pattern=_PATTERN,
    n_groups=2,
    window=8,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",
    sub_quadratic=True,
    remat="none",
)
