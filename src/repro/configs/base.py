"""Model / run configuration schema.

A model is a sequence of blocks described by *patterns*: ``head_pattern``
(unscanned prologue), ``pattern`` repeated ``n_groups`` times (stacked
params + ``jax.lax.scan`` — keeps HLO size and compile time flat in depth,
essential at 512 devices), and ``tail_pattern`` (unscanned epilogue).

Block spec = (mixer, ffn):
  mixer: "attn" | "attn_local" | "mla" | "rglru" | "ssd" | "attn_bidir"
  ffn:   "mlp" | "moe" | "none"
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

BlockSpec = Tuple[str, str]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: Optional[int] = None
    capacity_factor: float = 1.25
    group_size: int = 4096
    aux_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    head_dim: int = 64
    d_state: int = 128
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 64


@dataclass(frozen=True)
class RNNConfig:
    d_rnn: int
    conv_width: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The conv/mel frontend is a
    STUB: inputs are precomputed frame embeddings (B, source_len, d_model)."""

    n_layers: int
    source_len: int = 1500


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub (VLM): precomputed patch embeddings are inputs."""

    kind: str  # "siglip_stub"
    n_tokens: int  # e.g. 256 patches
    dim: int  # embedding dim delivered by the stub (== d_model after proj)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # layer structure
    pattern: Tuple[BlockSpec, ...]
    n_groups: int
    head_pattern: Tuple[BlockSpec, ...] = ()
    tail_pattern: Tuple[BlockSpec, ...] = ()
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention details
    rope_theta: float = 10_000.0
    rope_theta_local: Optional[float] = None  # local layers (gemma3: 10k vs 1M global)
    window: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    activation: str = "silu"
    norm_type: str = "rms"  # rms | layer (whisper)
    gated_mlp: bool = True  # False: plain w1/gelu/w2 (whisper)
    pos_embed: str = "rope"  # rope | learned (whisper)
    max_pos: int = 32_768  # learned-position table size
    norm_eps: float = 1e-6
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rnn: Optional[RNNConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    # training / lowering knobs
    remat: str = "full"  # none | full | dots
    # mixed precision: cast >=2D fp32 params to bf16 once per step before the
    # stack — halves FSDP all-gather wire bytes and gathered-weight buffers;
    # fp32 master weights live in the optimizer update (standard recipe).
    params_compute_dtype: str = "float32"  # float32 | bfloat16
    # False: Python-loop over layer groups instead of lax.scan.  Used by the
    # roofline harness at reduced depth so XLA's cost model sees every layer
    # (scan bodies are costed once regardless of trip count).
    scan_layers: bool = True
    # decode KV-cache storage dtype; fp8 halves cache HBM reads vs bf16
    # (per-tensor cast; scales would be per-block in a production fp8 path).
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | float8_e4m3fn
    use_flash_kernel: bool = False  # Pallas path (TPU target; interpret in tests)
    use_scan_kernels: bool = False  # Pallas rg_lru / ssd kernels
    attn_chunk_q: int = 512  # query-chunked attention; 0 = naive S^2 (baseline)
    chunked_loss_chunks: int = 8  # 0/1 = materialize full logits (baseline path)
    # Megatron-SP: residual-stream sharding (batch_axes, seq_axes) applied as
    # with_sharding_constraint at block boundaries.  Set by the dist layer;
    # None on CPU/smoke paths (no mesh context).
    act_pspec: Optional[Tuple[Any, Any]] = None
    sub_quadratic: bool = False  # eligible for long_500k cells

    # -- derived -------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def layer_specs(self) -> Tuple[BlockSpec, ...]:
        return self.head_pattern + self.pattern * self.n_groups + self.tail_pattern

    @property
    def n_layers(self) -> int:
        n = len(self.layer_specs)
        if self.encoder is not None:
            n += self.encoder.n_layers
        return n

    def scaled(self, **overrides) -> "ModelConfig":
        return replace(self, **overrides)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def get_shape_cell(name: str) -> ShapeCell:
    for cell in SHAPE_CELLS:
        if cell.name == name:
            return cell
    raise KeyError(name)
