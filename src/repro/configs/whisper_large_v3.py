"""whisper-large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356;
unverified].

32+32L d_model=1280 20H (MHA kv=20) head_dim=64 d_ff=5120 vocab=51866.
Conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
1500 precomputed frame embeddings.  LayerNorm, plain GELU MLP, learned
positions, QKV bias — whisper's actual block recipe.

Note: decode cells run the decoder mechanically at the assigned 32k context
(beyond whisper's trained 448-token horizon); the lowering is well-defined
and recorded as such in DESIGN.md.
"""

from .base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    pattern=(("attn", "mlp"),),
    n_groups=32,
    qkv_bias=True,
    norm_type="layer",
    gated_mlp=False,
    pos_embed="learned",
    max_pos=32_768,
    activation="gelu",
    encoder=EncoderConfig(n_layers=32, source_len=1500),
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke",
    family="audio",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "mlp"),),
    n_groups=2,
    qkv_bias=True,
    norm_type="layer",
    gated_mlp=False,
    pos_embed="learned",
    max_pos=128,
    activation="gelu",
    encoder=EncoderConfig(n_layers=2, source_len=16),
    remat="none",
)
