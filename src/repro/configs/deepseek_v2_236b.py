"""deepseek-v2-236b — MLA + fine-grained MoE: 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H, MLA (q_lora=1536, kv_lora=512, nope=128, rope=64,
v=128), d_ff_expert=1536 vocab=102400.  First layer dense (d_ff=12288).
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # dense first layer
    vocab=102400,
    head_pattern=(("mla", "mlp"),),
    pattern=(("mla", "moe"),),
    n_groups=59,
    rope_theta=10_000.0,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared=2,
        d_ff_shared=3072,
        capacity_factor=1.25,
        group_size=2048,  # bounds the (g,S,E,C) dispatch tensor at E=160
    ),
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=512,
    head_pattern=(("mla", "mlp"),),
    pattern=(("mla", "moe"),),
    n_groups=2,
    mla=MLAConfig(
        q_lora_rank=48,
        kv_lora_rank=32,
        qk_nope_head_dim=32,
        qk_rope_head_dim=16,
        v_head_dim=32,
    ),
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        d_ff_expert=64,
        n_shared=2,
        d_ff_shared=128,
        capacity_factor=1.5,
        group_size=64,
    ),
    remat="none",
)
