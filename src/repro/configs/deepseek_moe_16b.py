"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA kv=16) head_dim=128 d_ff_expert=1408 vocab=102400.
First layer is a dense FFN (d_ff=10944); remaining 27 layers are MoE.
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense first layer
    vocab=102400,
    head_pattern=(("attn", "mlp"),),
    pattern=(("attn", "moe"),),
    n_groups=27,
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        d_ff_shared=2816,
        capacity_factor=1.25,
        group_size=4096,
    ),
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke",
    family="moe",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=512,
    head_pattern=(("attn", "mlp"),),
    pattern=(("attn", "moe"),),
    n_groups=2,
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        d_ff_expert=64,
        n_shared=2,
        d_ff_shared=128,
        capacity_factor=1.5,
        group_size=64,
    ),
    remat="none",
)
