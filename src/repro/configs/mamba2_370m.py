"""mamba2-370m — attention-free SSM with SSD [arXiv:2405.21060; unverified].

48L d_model=1024 (attn-free) vocab=50280, d_inner=2048 (expand 2),
head_dim=64 (32 heads), d_state=128, SSD chunked scan.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    d_model=1024,
    n_heads=1,  # attention-free; placeholder
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    pattern=(("ssd", "none"),),
    n_groups=48,
    ssm=SSMConfig(d_inner=2048, head_dim=64, d_state=128, n_groups=1, conv_width=4, chunk=64),
    tie_embeddings=True,
    sub_quadratic=True,  # O(1) SSM state
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    d_model=128,
    n_heads=1,
    n_kv_heads=1,
    head_dim=32,
    d_ff=0,
    vocab=512,
    pattern=(("ssd", "none"),),
    n_groups=2,
    ssm=SSMConfig(d_inner=256, head_dim=32, d_state=16, n_groups=1, conv_width=4, chunk=8),
    tie_embeddings=True,
    sub_quadratic=True,
    remat="none",
)
