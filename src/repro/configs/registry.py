"""Architecture registry — ``--arch <id>`` resolution."""

from __future__ import annotations

from typing import Dict, List, Tuple

from .base import ModelConfig, SHAPE_CELLS, ShapeCell, get_shape_cell
from . import (
    deepseek_moe_16b,
    deepseek_v2_236b,
    gemma3_12b,
    mamba2_370m,
    mistral_nemo_12b,
    paligemma_3b,
    qwen2_5_32b,
    recurrentgemma_2b,
    whisper_large_v3,
    yi_34b,
)

_MODULES = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "yi-34b": yi_34b,
    "gemma3-12b": gemma3_12b,
    "qwen2.5-32b": qwen2_5_32b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "paligemma-3b": paligemma_3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "mamba2-370m": mamba2_370m,
    "whisper-large-v3": whisper_large_v3,
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        return _MODULES[arch].CONFIG
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; available: {list(ARCHS)}") from None


def get_smoke_config(arch: str) -> ModelConfig:
    try:
        return _MODULES[arch].SMOKE
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; available: {list(ARCHS)}") from None


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and the reason when it doesn't.

    long_500k requires sub-quadratic attention (assignment rule): full-
    attention archs skip it, with the skip recorded in DESIGN.md / the
    dry-run report."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: full/quadratic attention at 524k context"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    """Full 40-cell assignment (including skips)."""
    return [(arch, cell.name) for arch in ARCHS for cell in SHAPE_CELLS]
