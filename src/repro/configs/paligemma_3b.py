"""paligemma-3b — VLM: SigLIP frontend (STUB) + gemma-2b decoder
[arXiv:2407.07726; hf].

18L d_model=2048 8H (MQA kv=1) head_dim=256 d_ff=16384 vocab=257216.
The SigLIP tower is a stub per the assignment: ``input_specs`` supplies 256
precomputed patch embeddings already projected to d_model.
"""

from .base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    pattern=(("attn", "mlp"),),
    n_groups=18,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",
    frontend=FrontendConfig(kind="siglip_stub", n_tokens=256, dim=2048),
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke",
    family="vlm",
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "mlp"),),
    n_groups=2,
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",
    frontend=FrontendConfig(kind="siglip_stub", n_tokens=8, dim=128),
    remat="none",
)
