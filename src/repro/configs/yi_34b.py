"""yi-34b — dense llama-arch GQA [arXiv:2403.04652; hf].

60L d_model=7168 56H (GQA kv=8) head_dim=128 d_ff=20480 vocab=64000.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    pattern=(("attn", "mlp"),),
    n_groups=60,
    rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke",
    family="dense",
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "mlp"),),
    n_groups=2,
    rope_theta=5_000_000.0,
    remat="none",
)
