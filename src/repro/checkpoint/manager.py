"""Checkpointing: atomic, async, manifest-validated, reshard-on-load.

Layout:
    <root>/step_<N>.tmp/...      (written, then atomically renamed)
    <root>/step_<N>/
        manifest.json            tree structure, shapes, dtypes, step, extras
        arr_<i>.npy              one file per leaf (host-local full arrays)
    <root>/LATEST                text file with the newest valid step

Fault-tolerance properties:
  * atomic rename — a crash mid-write never corrupts the latest checkpoint;
  * manifest validation on restore — partial/corrupt dirs are skipped and
    the previous valid step is used (`restore_latest` walks backwards);
  * async writer thread — training is blocked only for the host gather;
  * reshard-on-load — arrays are re-`device_put` with the *target* sharding,
    so a checkpoint saved on mesh A restores onto mesh B (elastic scaling).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16, fp8) natively; store a
# bit-preserving unsigned view and restore via .view(logical_dtype).
_BITCAST = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _to_storage(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind == "V" or arr.dtype.names is None and not arr.dtype.isbuiltin:
        return arr.view(_BITCAST[arr.dtype.itemsize])
    return arr


def _from_storage(raw: np.ndarray, dtype_name: str) -> np.ndarray:
    try:
        dtype = np.dtype(dtype_name)
    except TypeError:
        dtype = np.dtype(getattr(ml_dtypes, dtype_name))
    if raw.dtype != dtype and raw.dtype.kind == "u" and raw.dtype.itemsize == dtype.itemsize:
        return raw.view(dtype)
    return raw.astype(dtype) if raw.dtype != dtype else raw


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree) -> List[str]:
    import jax.tree_util as jtu

    return [jtu.keystr(p) for p, _ in jtu.tree_leaves_with_path(tree)]


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None) -> None:
        """Snapshot to host memory synchronously; write to disk (a)sync."""
        self.wait()  # one outstanding write at a time
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(leaf) for leaf in leaves]  # device->host gather
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "paths": _tree_paths(tree),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "extras": extras or {},
            "time": time.time(),
            "complete": True,
        }
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, manifest), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_leaves, manifest)
            self.wait()  # surface write errors immediately on the sync path

    def _write(self, step: int, host_leaves: List[np.ndarray], manifest: Dict) -> None:
        try:
            tmp = os.path.join(self.root, f"step_{step}.tmp")
            final = os.path.join(self.root, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), _to_storage(arr))
            with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                json.dump(manifest, fh)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            with open(os.path.join(self.root, "LATEST.tmp"), "w") as fh:
                fh.write(str(step))
            os.replace(os.path.join(self.root, "LATEST.tmp"), os.path.join(self.root, "LATEST"))
            self._gc()
        except BaseException as exc:  # noqa: BLE001 - surfaced on wait()
            self._error = exc

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err!r}") from err

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"), ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if self._valid(os.path.join(self.root, name)):
                    out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def _valid(self, path: str) -> bool:
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            return False
        try:
            with open(mpath) as fh:
                manifest = json.load(fh)
            if not manifest.get("complete"):
                return False
            n = len(manifest["shapes"])
            return all(os.path.exists(os.path.join(path, f"arr_{i}.npy")) for i in range(n))
        except (json.JSONDecodeError, KeyError, OSError):
            return False

    def restore(
        self, step: int, target_tree: Any, shardings: Optional[Any] = None
    ) -> Tuple[Any, Dict]:
        """Restore ``step`` into the structure of ``target_tree``; when
        ``shardings`` is given, leaves are device_put with the *target*
        sharding (reshard-on-load: mesh may differ from save time)."""
        path = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        leaves, treedef = jax.tree.flatten(target_tree)
        if len(leaves) != len(manifest["shapes"]):
            raise ValueError(
                f"checkpoint has {len(manifest['shapes'])} leaves, target has {len(leaves)}"
            )
        shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
        restored = []
        for i, (tgt, shard) in enumerate(zip(leaves, shard_leaves)):
            raw = np.load(os.path.join(path, f"arr_{i}.npy"))
            arr = _from_storage(raw, manifest["dtypes"][i])
            if list(arr.shape) != list(tgt.shape):
                raise ValueError(f"leaf {i}: checkpoint {arr.shape} != target {tgt.shape}")
            if arr.dtype != tgt.dtype:
                arr = arr.astype(tgt.dtype)
            if shard is not None:
                restored.append(jax.device_put(arr, shard))
            else:
                restored.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, restored), manifest["extras"]

    def restore_latest(
        self, target_tree: Any, shardings: Optional[Any] = None
    ) -> Optional[Tuple[int, Any, Dict]]:
        """Restore the newest valid checkpoint, walking backwards past any
        corrupt ones.  Returns None when no checkpoint exists (fresh start)."""
        for step in reversed(self.steps()):
            try:
                tree, extras = self.restore(step, target_tree, shardings)
                return step, tree, extras
            except (ValueError, OSError):
                continue
        return None
